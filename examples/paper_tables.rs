//! Regenerate every table and figure of the paper's evaluation section.
//!
//! ```sh
//! cargo run --release --example paper_tables            # all exhibits
//! cargo run --release --example paper_tables -- fig7b   # one exhibit
//! ```
//!
//! Table I additionally needs the AOT artifacts (`make artifacts`).

use swiftkv::model::{LlmConfig, TinyModel, WeightStore};
use swiftkv::report;
use swiftkv::runtime::{artifacts_available, default_artifacts_dir};
use swiftkv::sim::ArchConfig;

fn main() -> anyhow::Result<()> {
    let only = std::env::args().nth(1);
    let arch = ArchConfig::default();
    let want = |name: &str| only.as_deref().is_none_or(|o| o == name);

    if want("fig7a") {
        println!("{}", report::fig7a(&arch));
    }
    if want("fig7b") {
        println!("{}", report::fig7b(&arch));
    }
    if want("explut") {
        println!("{}", report::exp_lut_error());
    }
    if want("table1") {
        if artifacts_available() {
            let tm = TinyModel::load(&WeightStore::load(&default_artifacts_dir())?)?;
            let (table, _) = report::table1(&tm, 20, 48);
            println!("{table}");
        } else {
            println!("Table I skipped — run `make artifacts` first\n");
        }
    }
    if want("table2") {
        println!("{}", report::table2(&arch));
    }
    if want("fig8a") {
        println!("{}", report::fig8a(&arch, &LlmConfig::llama2_7b(), 512));
    }
    if want("table3") {
        println!("{}", report::table3(&arch));
    }
    if want("fig8b") {
        println!("{}", report::fig8b(&arch));
    }
    if want("table4") {
        println!("{}", report::table4(&arch));
    }
    Ok(())
}
