//! Hand-written AVX2 (+FMA) microkernels behind [`super::isa`].
//!
//! Registered only when `is_x86_feature_detected!("avx2") && ("fma")`
//! both hold (see [`super::isa::table_for`]), so every wrapper here can
//! soundly call its `#[target_feature]` inner function.
//!
//! Numerics, per kernel (the contract [`super::isa`] documents and
//! `tests/prop_simd_dispatch.rs` enforces):
//!
//! - [`dot_f32`] uses FMA and 8-wide partial sums — equal to the scalar
//!   kernel within re-association noise, not bit-identical.
//! - [`axpy_f32`] / [`scale_axpy_f32`] / [`scale_f32`] deliberately use
//!   `mul` **then** `add` (no FMA) so each element sees exactly the
//!   scalar operation order — bit-identical.
//! - The Q15.17 kernels emulate the scalar path lane-for-lane: exact
//!   64-bit products (`_mm256_mul_epi32`), `+2¹⁶` rounding, an emulated
//!   64-bit arithmetic `>> 17`, and the same clamp/saturate order —
//!   bit-exact.
//! - [`dot_i8`] / [`w4a8_col`] widen i8→i16 and use `_mm256_madd_epi16`
//!   (exact: |pair sum| ≤ 2·127² ≪ 2¹⁵·2¹⁶) with i32 accumulators —
//!   bit-exact while callers keep `len·|a|·|b| ≪ 2³¹`, which the W4A8
//!   nibble weights (|w| ≤ 8) and `GEMM_KC`-bounded panels guarantee.
//!
//! lint: hotpath

#![allow(unsafe_code)]
// The pure-lane helpers wrap their bodies in `unsafe {}` so they build
// under `deny(unsafe_op_in_unsafe_fn)` on toolchains where intrinsic
// calls are unsafe ops; newer toolchains (safe target-feature
// intrinsics) would flag those blocks as unused.
#![allow(unused_unsafe)]

use std::arch::x86_64::*;

use crate::fxp::Fxp32;

use super::isa::{Isa, KernelTable};

/// The AVX2 kernel table (see module docs for the numerics contract).
pub static TABLE: KernelTable = KernelTable {
    name: "avx2",
    isa: Isa::Avx2,
    dot_f32,
    axpy_f32,
    scale_axpy_f32,
    scale_f32,
    dot_fxp_wide,
    axpy_fxp,
    scale_axpy_fxp,
    dot_i8,
    w4a8_col,
};

// ---------------------------------------------------------------- f32 --

fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: this table is only registered after runtime detection of
    // avx2+fma (isa::table_for), so the features are present here.
    unsafe { dot_f32_avx2(a, b) }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 and FMA. `a` and `b` must
/// have equal lengths (the dispatch wrapper debug-asserts this; the
/// loops below index only through `min(a.len(), b.len())` regardless).
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: every pointer offset is bounds-guarded — the vector loops
    // require `i + 16 <= n` / `i + 8 <= n` and the scalar tail `i < n`,
    // with `n = a.len() = b.len()`.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            let (xa, xb) = (_mm256_loadu_ps(pa.add(i + 8)), _mm256_loadu_ps(pb.add(i + 8)));
            acc1 = _mm256_fmadd_ps(xa, xb, acc1);
            i += 16;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let mut s = hsum256_ps(_mm256_add_ps(acc0, acc1));
        while i < n {
            s += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        s
    }
}

fn axpy_f32(beta: f32, y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    // SAFETY: registration is gated on runtime avx2+fma detection.
    unsafe { axpy_f32_avx2(beta, y, x) }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2. `y` and `x` must have
/// equal lengths (loops index only through `min(y.len(), x.len())`).
#[target_feature(enable = "avx2")]
unsafe fn axpy_f32_avx2(beta: f32, y: &mut [f32], x: &[f32]) {
    // SAFETY: all loads/stores stay inside `y`/`x` — the vector loop
    // requires `i + 8 <= n` and the tail `i < n`, with `n = y.len()`.
    unsafe {
        let n = y.len();
        let py = y.as_mut_ptr();
        let px = x.as_ptr();
        let vb = _mm256_set1_ps(beta);
        let mut i = 0usize;
        while i + 8 <= n {
            // mul then add — NOT fmadd — so each lane is bit-identical to
            // the scalar `y[i] += beta * x[i]`
            let yv = _mm256_loadu_ps(py.add(i));
            let xv = _mm256_loadu_ps(px.add(i));
            _mm256_storeu_ps(py.add(i), _mm256_add_ps(yv, _mm256_mul_ps(vb, xv)));
            i += 8;
        }
        while i < n {
            *py.add(i) += beta * *px.add(i);
            i += 1;
        }
    }
}

fn scale_axpy_f32(alpha: f32, y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    // SAFETY: registration is gated on runtime avx2+fma detection.
    unsafe { scale_axpy_f32_avx2(alpha, y, x) }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2. `y` and `x` must have
/// equal lengths (loops index only through `min(y.len(), x.len())`).
#[target_feature(enable = "avx2")]
unsafe fn scale_axpy_f32_avx2(alpha: f32, y: &mut [f32], x: &[f32]) {
    // SAFETY: all loads/stores stay inside `y`/`x` — the vector loop
    // requires `i + 8 <= n` and the tail `i < n`, with `n = y.len()`.
    unsafe {
        let n = y.len();
        let py = y.as_mut_ptr();
        let px = x.as_ptr();
        let va = _mm256_set1_ps(alpha);
        let mut i = 0usize;
        while i + 8 <= n {
            // mul then add (no FMA): bit-identical to `y[i] = alpha*y[i] + x[i]`
            let yv = _mm256_loadu_ps(py.add(i));
            let xv = _mm256_loadu_ps(px.add(i));
            _mm256_storeu_ps(py.add(i), _mm256_add_ps(_mm256_mul_ps(va, yv), xv));
            i += 8;
        }
        while i < n {
            *py.add(i) = alpha * *py.add(i) + *px.add(i);
            i += 1;
        }
    }
}

fn scale_f32(alpha: f32, y: &mut [f32]) {
    // SAFETY: registration is gated on runtime avx2+fma detection.
    unsafe { scale_f32_avx2(alpha, y) }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
unsafe fn scale_f32_avx2(alpha: f32, y: &mut [f32]) {
    // SAFETY: all loads/stores stay inside `y` — the vector loop
    // requires `i + 8 <= n` and the tail `i < n`, with `n = y.len()`.
    unsafe {
        let n = y.len();
        let py = y.as_mut_ptr();
        let va = _mm256_set1_ps(alpha);
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(py.add(i), _mm256_mul_ps(va, _mm256_loadu_ps(py.add(i))));
            i += 8;
        }
        while i < n {
            *py.add(i) *= alpha;
            i += 1;
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (pure lane arithmetic — no
/// memory access).
#[target_feature(enable = "avx2")]
unsafe fn hsum256_ps(v: __m256) -> f32 {
    // SAFETY: register-only intrinsics; no memory is touched.
    unsafe {
        let mut s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
        s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
        _mm_cvtss_f32(s)
    }
}

// ------------------------------------------------------------- Q15.17 --

fn dot_fxp_wide(a: &[Fxp32], b: &[Fxp32]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: registration is gated on runtime avx2+fma detection;
    // Fxp32 is repr(transparent) over i32 so the pointer cast is sound.
    unsafe { dot_fxp_wide_avx2(a, b) }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2. `a` and `b` must have
/// equal lengths (loops index only through `min(a.len(), b.len())`).
#[target_feature(enable = "avx2")]
unsafe fn dot_fxp_wide_avx2(a: &[Fxp32], b: &[Fxp32]) -> i64 {
    // SAFETY: `Fxp32` is repr(transparent) over i32 so the element
    // pointers reinterpret soundly, and every offset is bounds-guarded
    // by `i + 8 <= n` / `i < n` with `n = a.len()`.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr() as *const i32;
        let pb = b.as_ptr() as *const i32;
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 8 <= n {
            let va = _mm256_loadu_si256(pa.add(i) as *const __m256i);
            let vb = _mm256_loadu_si256(pb.add(i) as *const __m256i);
            // exact 32×32→64 products: even lanes directly, odd lanes after
            // a logical >>32 (mul_epi32 sign-extends the low 32 bits, so the
            // zero-filled high halves are ignored)
            let even = _mm256_mul_epi32(va, vb);
            let odd = _mm256_mul_epi32(_mm256_srli_epi64::<32>(va), _mm256_srli_epi64::<32>(vb));
            acc0 = _mm256_add_epi64(acc0, even);
            acc1 = _mm256_add_epi64(acc1, odd);
            i += 8;
        }
        let mut acc = hsum256_epi64(_mm256_add_epi64(acc0, acc1));
        while i < n {
            acc += *pa.add(i) as i64 * *pb.add(i) as i64;
            i += 1;
        }
        acc
    }
}

fn axpy_fxp(b: Fxp32, y: &mut [Fxp32], x: &[Fxp32]) {
    debug_assert_eq!(y.len(), x.len());
    // SAFETY: registration is gated on runtime avx2+fma detection;
    // Fxp32 is repr(transparent) over i32 so the pointer casts are sound.
    unsafe { axpy_fxp_avx2(b, y, x) }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2. `y` and `x` must have
/// equal lengths (loops index only through `min(y.len(), x.len())`).
#[target_feature(enable = "avx2")]
unsafe fn axpy_fxp_avx2(b: Fxp32, y: &mut [Fxp32], x: &[Fxp32]) {
    // SAFETY: `Fxp32` is repr(transparent) over i32 so the element
    // pointers reinterpret soundly; the vector loop requires
    // `i + 4 <= n` with `n = y.len()` and the scalar tail uses safe
    // slicing.
    unsafe {
        let n = y.len();
        let py = y.as_mut_ptr() as *mut i32;
        let px = x.as_ptr() as *const i32;
        let vb = _mm256_set1_epi64x(b.raw() as i64);
        // rounding bias 1 << (FRAC_BITS - 1) with FRAC_BITS = 17
        let half = _mm256_set1_epi64x(1i64 << 16);
        let mut i = 0usize;
        while i + 4 <= n {
            let xv = _mm256_cvtepi32_epi64(_mm_loadu_si128(px.add(i) as *const __m128i));
            // prod = (b.raw * x.raw + half) >> 17, clamped to i32 — exactly
            // the scalar axpy_scalar computation, 4 lanes at a time
            let mut prod = _mm256_mul_epi32(vb, xv);
            prod = _mm256_add_epi64(prod, half);
            prod = sra17_epi64(prod);
            prod = clamp_i32_epi64(prod);
            // y.sat_add(prod): both operands are in i32 range, so the i64
            // sum is exact and one more clamp realizes the saturation
            let yv = _mm256_cvtepi32_epi64(_mm_loadu_si128(py.add(i) as *const __m128i));
            let sum = clamp_i32_epi64(_mm256_add_epi64(yv, prod));
            _mm_storeu_si128(py.add(i) as *mut __m128i, pack_low32_epi64(sum));
            i += 4;
        }
        if i < n {
            crate::fxp::vector::axpy_scalar(b, &mut y[i..], &x[i..]);
        }
    }
}

fn scale_axpy_fxp(a: Fxp32, y: &mut [Fxp32], x: &[Fxp32]) {
    debug_assert_eq!(y.len(), x.len());
    // SAFETY: registration is gated on runtime avx2+fma detection;
    // Fxp32 is repr(transparent) over i32 so the pointer casts are sound.
    unsafe { scale_axpy_fxp_avx2(a, y, x) }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2. `y` and `x` must have
/// equal lengths (loops index only through `min(y.len(), x.len())`).
#[target_feature(enable = "avx2")]
unsafe fn scale_axpy_fxp_avx2(a: Fxp32, y: &mut [Fxp32], x: &[Fxp32]) {
    // SAFETY: `Fxp32` is repr(transparent) over i32 so the element
    // pointers reinterpret soundly; the vector loop requires
    // `i + 4 <= n` with `n = y.len()` and the scalar tail uses safe
    // slicing.
    unsafe {
        let n = y.len();
        let py = y.as_mut_ptr() as *mut i32;
        let px = x.as_ptr() as *const i32;
        let va = _mm256_set1_epi64x(a.raw() as i64);
        let half = _mm256_set1_epi64x(1i64 << 16);
        let mut i = 0usize;
        while i + 4 <= n {
            // prod = round(a·y) clamped, then sat_add(x) — the exact scalar
            // scale_axpy_scalar order with the roles of y and x swapped
            // relative to axpy
            let yv = _mm256_cvtepi32_epi64(_mm_loadu_si128(py.add(i) as *const __m128i));
            let mut prod = _mm256_mul_epi32(va, yv);
            prod = _mm256_add_epi64(prod, half);
            prod = sra17_epi64(prod);
            prod = clamp_i32_epi64(prod);
            let xv = _mm256_cvtepi32_epi64(_mm_loadu_si128(px.add(i) as *const __m128i));
            let sum = clamp_i32_epi64(_mm256_add_epi64(prod, xv));
            _mm_storeu_si128(py.add(i) as *mut __m128i, pack_low32_epi64(sum));
            i += 4;
        }
        if i < n {
            crate::fxp::vector::scale_axpy_scalar(a, &mut y[i..], &x[i..]);
        }
    }
}

/// Arithmetic `>> 17` on four i64 lanes (AVX2 has no `sra` for epi64):
/// logical shift, then OR the sign bits back into the top 17 positions.
///
/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (pure lane arithmetic — no
/// memory access).
#[target_feature(enable = "avx2")]
unsafe fn sra17_epi64(v: __m256i) -> __m256i {
    // SAFETY: register-only intrinsics; no memory is touched.
    unsafe {
        let logical = _mm256_srli_epi64::<17>(v);
        let sign = _mm256_cmpgt_epi64(_mm256_setzero_si256(), v);
        _mm256_or_si256(logical, _mm256_slli_epi64::<47>(sign))
    }
}

/// Clamp four i64 lanes into `[i32::MIN, i32::MAX]`.
///
/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (pure lane arithmetic — no
/// memory access).
#[target_feature(enable = "avx2")]
unsafe fn clamp_i32_epi64(v: __m256i) -> __m256i {
    // SAFETY: register-only intrinsics; no memory is touched.
    unsafe {
        let maxv = _mm256_set1_epi64x(i32::MAX as i64);
        let minv = _mm256_set1_epi64x(i32::MIN as i64);
        let v = _mm256_blendv_epi8(v, maxv, _mm256_cmpgt_epi64(v, maxv));
        _mm256_blendv_epi8(minv, v, _mm256_cmpgt_epi64(v, minv))
    }
}

/// Low 32 bits of each of the four i64 lanes, packed into a __m128i.
///
/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (pure lane arithmetic — no
/// memory access).
#[target_feature(enable = "avx2")]
unsafe fn pack_low32_epi64(v: __m256i) -> __m128i {
    // SAFETY: register-only intrinsics; no memory is touched.
    unsafe {
        let idx = _mm256_setr_epi32(0, 2, 4, 6, 0, 0, 0, 0);
        _mm256_castsi256_si128(_mm256_permutevar8x32_epi32(v, idx))
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
unsafe fn hsum256_epi64(v: __m256i) -> i64 {
    // SAFETY: the store targets a stack buffer of exactly 4 i64 lanes
    // (32 bytes, the width of one __m256i).
    unsafe {
        let mut buf = [0i64; 4];
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, v);
        buf[0] + buf[1] + buf[2] + buf[3]
    }
}

// ------------------------------------------------------- INT8 / W4A8 --

fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: registration is gated on runtime avx2+fma detection.
    unsafe { dot_i8_avx2(a, b) }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2. `a` and `b` must have
/// equal lengths (loops index only through `min(a.len(), b.len())`).
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
    // SAFETY: every pointer offset is bounds-guarded by `i + 32 <= n`
    // in the vector loop and `i < n` in the tail, with `n = a.len()`.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 32 <= n {
            let va = _mm256_loadu_si256(pa.add(i) as *const __m256i);
            let vb = _mm256_loadu_si256(pb.add(i) as *const __m256i);
            // widen i8→i16 and madd: each i32 lane gets an exact pair sum
            let lo = _mm256_madd_epi16(
                _mm256_cvtepi8_epi16(_mm256_castsi256_si128(va)),
                _mm256_cvtepi8_epi16(_mm256_castsi256_si128(vb)),
            );
            let hi = _mm256_madd_epi16(
                _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(va)),
                _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(vb)),
            );
            acc = _mm256_add_epi32(acc, _mm256_add_epi32(lo, hi));
            i += 32;
        }
        let mut s = hsum256_epi32(acc);
        while i < n {
            s += *pa.add(i) as i32 * *pb.add(i) as i32;
            i += 1;
        }
        s
    }
}

fn w4a8_col(col: &[u8], din: usize, xs: &[i8]) -> i32 {
    debug_assert_eq!(xs.len(), din);
    debug_assert!(col.len() >= din.div_ceil(2));
    // SAFETY: registration is gated on runtime avx2+fma detection; the
    // asserts above pin the packed-column and activation lengths the
    // inner kernel indexes through.
    unsafe { w4a8_col_avx2(col, din, xs) }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2, that `xs.len() == din`,
/// and that `col` holds at least `din.div_ceil(2)` packed bytes (the
/// dispatch wrapper debug-asserts both).
#[target_feature(enable = "avx2")]
unsafe fn w4a8_col_avx2(col: &[u8], din: usize, xs: &[i8]) -> i32 {
    // SAFETY: the vector loop reads 16 packed bytes (32 activations) at
    // `byte + 16 <= pairs`; the byte tail stops at `pairs = din/2` and
    // the odd-nibble epilogue reads exactly `col[pairs]` / `xs[din-1]`
    // — all within the lengths the caller guarantees.
    unsafe {
        let pairs = din / 2;
        let pc = col.as_ptr();
        let px = xs.as_ptr();
        let nib_mask = _mm_set1_epi8(0x0F);
        let sign_bit = _mm_set1_epi8(8);
        let mut acc = _mm256_setzero_si256();
        let mut byte = 0usize;
        while byte + 16 <= pairs {
            let packed = _mm_loadu_si128(pc.add(byte) as *const __m128i);
            // split nibbles and sign-extend 4→8 bits via (v ^ 8) - 8
            let lo = _mm_and_si128(packed, nib_mask);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(packed), nib_mask);
            let lo = _mm_sub_epi8(_mm_xor_si128(lo, sign_bit), sign_bit);
            let hi = _mm_sub_epi8(_mm_xor_si128(hi, sign_bit), sign_bit);
            // interleave back to natural weight order (low nibble first):
            // w[2k] = lo nibble of byte k, w[2k+1] = hi nibble of byte k
            let w0 = _mm_unpacklo_epi8(lo, hi);
            let w1 = _mm_unpackhi_epi8(lo, hi);
            let x0 = _mm_loadu_si128(px.add(2 * byte) as *const __m128i);
            let x1 = _mm_loadu_si128(px.add(2 * byte + 16) as *const __m128i);
            let p0 = _mm256_madd_epi16(_mm256_cvtepi8_epi16(w0), _mm256_cvtepi8_epi16(x0));
            let p1 = _mm256_madd_epi16(_mm256_cvtepi8_epi16(w1), _mm256_cvtepi8_epi16(x1));
            acc = _mm256_add_epi32(acc, _mm256_add_epi32(p0, p1));
            byte += 16;
        }
        let mut s = hsum256_epi32(acc);
        // remaining complete bytes, then the odd trailing low nibble
        while byte < pairs {
            let b = *pc.add(byte);
            let w_lo = (((b & 0x0F) ^ 8) as i8 - 8) as i32;
            let w_hi = (((b >> 4) ^ 8) as i8 - 8) as i32;
            s += w_lo * *px.add(2 * byte) as i32;
            s += w_hi * *px.add(2 * byte + 1) as i32;
            byte += 1;
        }
        if din % 2 == 1 {
            let b = *pc.add(pairs);
            let w_lo = (((b & 0x0F) ^ 8) as i8 - 8) as i32;
            s += w_lo * *px.add(din - 1) as i32;
        }
        s
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (pure lane arithmetic — no
/// memory access).
#[target_feature(enable = "avx2")]
unsafe fn hsum256_epi32(v: __m256i) -> i32 {
    // SAFETY: register-only intrinsics; no memory is touched.
    unsafe {
        let mut s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
        s = _mm_add_epi32(s, _mm_srli_si128::<8>(s));
        s = _mm_add_epi32(s, _mm_srli_si128::<4>(s));
        _mm_cvtsi128_si32(s)
    }
}
