//! SwiftKV single-pass attention — Eqs. (5)–(8) in f32.
//!
//! Every `(k_t, v_t)` is consumed exactly once in a uniform per-token
//! update of the `(μ, Z, Y)` state; no scores are materialized and there
//! is no second pass. The division is deferred to a single final
//! normalization (Eq. 8). This is the algorithm the SwiftKV core
//! executes; [`super::fxp_swiftkv`] is the same recurrence in the
//! accelerator's Q15.17 arithmetic.

use super::{dot_f32, HeadProblem};

/// Running state of the recurrence: `μ` (running max), `Z` (denominator),
/// `Y` (unnormalized output).
#[derive(Debug, Clone)]
pub struct SwiftKvState {
    pub mu: f32,
    pub z: f32,
    pub y: Vec<f32>,
    /// Tokens consumed so far (diagnostics / invariant checks).
    pub consumed: usize,
}

impl SwiftKvState {
    /// Initial state: μ = −∞, Z = 0, Y = 0 (§III).
    pub fn new(d: usize) -> Self {
        SwiftKvState {
            mu: f32::NEG_INFINITY,
            z: 0.0,
            y: vec![0.0; d],
            consumed: 0,
        }
    }

    /// Consume one `(s_t, v_t)` pair — the compare-and-select + update
    /// parts of the SwiftKV core (Fig. 3), Eqs. (6)/(7).
    #[inline]
    pub fn update(&mut self, s_t: f32, v_t: &[f32]) {
        debug_assert_eq!(v_t.len(), self.y.len());
        if self.consumed == 0 {
            // μ₁ = s₁ branch: β = exp(0) = 1
            self.mu = s_t;
            self.z = 1.0;
            self.y.copy_from_slice(v_t);
        } else if s_t <= self.mu {
            // Eq. (6): fold the new token in at weight β ∈ (0, 1]
            let beta = (s_t - self.mu).exp();
            self.z += beta;
            for (y, &v) in self.y.iter_mut().zip(v_t) {
                *y += beta * v;
            }
        } else {
            // Eq. (7): rescale history by α ∈ (0, 1), new token at weight 1
            let alpha = (self.mu - s_t).exp();
            self.z = alpha * self.z + 1.0;
            for (y, &v) in self.y.iter_mut().zip(v_t) {
                *y = alpha * *y + v;
            }
            self.mu = s_t;
        }
        self.consumed += 1;
    }

    /// Eq. (8): the deferred one-time normalization.
    pub fn finalize(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.y.len()];
        self.finalize_into(&mut out);
        out
    }

    /// Eq. (8) into a caller-owned buffer (no allocation) — same
    /// element-wise `y / Z` as [`Self::finalize`], bit-identical.
    pub fn finalize_into(&self, out: &mut [f32]) {
        assert!(self.consumed > 0, "finalize before any token");
        assert_eq!(out.len(), self.y.len());
        for (o, &y) in out.iter_mut().zip(&self.y) {
            *o = y / self.z;
        }
    }
}

/// Full single-pass attention over a head problem.
pub fn attend(p: &HeadProblem) -> Vec<f32> {
    let scale = p.scale();
    let mut st = SwiftKvState::new(p.d);
    for t in 0..p.len {
        let s_t = dot_f32(p.q, p.key(t)) * scale; // Eq. (5)
        st.update(s_t, p.value(t));
    }
    st.finalize()
}

/// Incremental decode-style usage: extend an existing state by the KV rows
/// in `[from, to)` (used by the serving path, where each generated token
/// appends one row and the state picks up where it left off).
pub fn extend(st: &mut SwiftKvState, p: &HeadProblem, from: usize, to: usize) {
    let scale = p.scale();
    for t in from..to.min(p.len) {
        let s_t = dot_f32(p.q, p.key(t)) * scale;
        st.update(s_t, p.value(t));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::native;
    use crate::attention::testutil::{assert_close, ProblemData};

    #[test]
    fn matches_native_attention() {
        for seed in 0..8 {
            let data = ProblemData::random(seed, 32, 100 + seed as usize * 17, 1.0);
            let p = data.problem();
            assert_close(
                &attend(&p),
                &native::attend(&p),
                1e-5,
                &format!("seed {seed}"),
            );
        }
    }

    #[test]
    fn rescale_factors_stay_in_unit_interval() {
        // replicate the recurrence, asserting the §III invariant that every
        // exp argument is ≤ 0 (so α, β ∈ (0, 1])
        let data = ProblemData::random(42, 16, 200, 10.0);
        let p = data.problem();
        let scale = p.scale();
        let mut mu = f32::NEG_INFINITY;
        for t in 0..p.len {
            let s = crate::attention::dot_f32(p.q, p.key(t)) * scale;
            if t == 0 {
                mu = s;
                continue;
            }
            let arg = if s <= mu { s - mu } else { mu - s };
            assert!(arg <= 0.0, "exp argument positive at t={t}");
            mu = mu.max(s);
        }
    }

    #[test]
    fn z_positive_and_at_most_len() {
        let data = ProblemData::random(9, 8, 77, 2.0);
        let p = data.problem();
        let scale = p.scale();
        let mut st = SwiftKvState::new(p.d);
        for t in 0..p.len {
            st.update(crate::attention::dot_f32(p.q, p.key(t)) * scale, p.value(t));
            assert!(st.z > 0.0);
            // every term exp(s_t − μ) ≤ 1 ⇒ Z ≤ #tokens
            assert!(st.z <= (t + 1) as f32 + 1e-4);
        }
    }

    #[test]
    fn extend_equals_one_shot() {
        let data = ProblemData::random(5, 16, 96, 1.0);
        let p = data.problem();
        let mut st = SwiftKvState::new(p.d);
        extend(&mut st, &p, 0, 30);
        extend(&mut st, &p, 30, 96);
        assert_close(&st.finalize(), &attend(&p), 1e-6, "extend");
    }

    #[test]
    fn output_is_convex_combination() {
        // each output coordinate lies within [min, max] of the value column
        let data = ProblemData::random(13, 8, 50, 1.0);
        let p = data.problem();
        let out = attend(&p);
        for j in 0..p.d {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for t in 0..p.len {
                lo = lo.min(p.value(t)[j]);
                hi = hi.max(p.value(t)[j]);
            }
            assert!(
                out[j] >= lo - 1e-5 && out[j] <= hi + 1e-5,
                "coordinate {j} escapes hull"
            );
        }
    }

    #[test]
    fn monotone_score_order_independence() {
        // shuffling KV rows must not change the output (softmax symmetry)
        let data = ProblemData::random(21, 8, 40, 1.0);
        let p = data.problem();
        let base = attend(&p);

        let mut idx: Vec<usize> = (0..p.len).collect();
        idx.reverse();
        let k2: Vec<f32> = idx.iter().flat_map(|&t| p.key(t).to_vec()).collect();
        let v2: Vec<f32> = idx.iter().flat_map(|&t| p.value(t).to_vec()).collect();
        let p2 = HeadProblem::new(p.q, &k2, &v2, p.d, p.len);
        assert_close(&attend(&p2), &base, 1e-5, "reversed order");
    }
}
