//! Special Function Unit cycle model (§IV-A): EM-Add, quantization /
//! casting (FXP32/INT32/INT8), Hadamard product, SiLU, RMS normalization.
//!
//! All are lane-parallel vector ops at `sfu_lanes` elements per cycle with
//! a short pipeline; RMSNorm needs a reduction pass plus an rsqrt.

use super::ArchConfig;

/// Elementwise op over `n` elements (EM-Add, Hadamard, SiLU, casts).
pub fn elementwise_cycles(arch: &ArchConfig, n: usize) -> u64 {
    (n.div_ceil(arch.sfu_lanes)) as u64 + 4
}

/// Quantize/cast a vector (same structure as elementwise; kept separate
/// for breakdown reporting).
pub fn cast_cycles(arch: &ArchConfig, n: usize) -> u64 {
    elementwise_cycles(arch, n)
}

/// RMS normalization: square-accumulate pass + rsqrt + scale pass.
pub fn rmsnorm_cycles(arch: &ArchConfig, n: usize) -> u64 {
    let pass = (n.div_ceil(arch.sfu_lanes)) as u64;
    pass + arch.div_latency + pass + 4
}

/// EM-Add reduction of the 32 processors' partial sums (tree over
/// `n_processors` values, one output element per cycle when pipelined —
/// folded into the GEMV pipeline; exposed for standalone accounting).
pub fn emadd_tree_latency(arch: &ArchConfig) -> u64 {
    (arch.n_processors as f64).log2().ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_lane_parallel() {
        let a = ArchConfig::default();
        assert_eq!(elementwise_cycles(&a, 4096), 128 + 4);
        assert_eq!(elementwise_cycles(&a, 1), 1 + 4);
    }

    #[test]
    fn rmsnorm_two_passes() {
        let a = ArchConfig::default();
        let c = rmsnorm_cycles(&a, 4096);
        assert_eq!(c, 128 + a.div_latency + 128 + 4);
    }

    #[test]
    fn emadd_tree_depth() {
        let a = ArchConfig::default();
        assert_eq!(emadd_tree_latency(&a), 5); // log2(32)
    }
}
