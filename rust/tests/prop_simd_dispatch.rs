//! Property tests: the runtime-dispatched SIMD microkernels
//! (`kernels::isa::active()`) versus the portable scalar table, swept
//! over lengths straddling every vector width in play (0, 1, around 4/8
//! f32 lanes, around the 16/32-wide integer strides, plus long odd
//! tails). The numerics contract under test is the one the kernel docs
//! promise:
//!
//! - FXP32 dot/axpy/scale_axpy and the INT8/W4A8 integer kernels are
//!   **bit-exact** across every dispatch target (integer arithmetic
//!   reassociates freely);
//! - f32 `axpy`/`scale_axpy`/`scale` are **bit-identical** (the AVX2
//!   kernels deliberately use mul-then-add, never FMA, in the same
//!   element order);
//! - f32 `dot` may re-associate (SIMD accumulators + FMA), so it gets a
//!   documented relative tolerance instead of bit equality.
//!
//! On a machine where only the scalar table is available the native and
//! scalar tables coincide and these checks pass trivially — the suite
//! is meaningful on AVX2 hosts (CI runs it under both `SWIFTKV_ISA`
//! settings) and harmless elsewhere.

use swiftkv::fxp::{vector, Fxp32};
use swiftkv::kernels::isa::{self, Isa};
use swiftkv::quant::gemv::GEMM_KC;
use swiftkv::quant::{
    gemm_w4a8_raw_into, gemv_w4a8_raw_into, pack_int4, quantize_int8_into, Int4Matrix,
};
use swiftkv::util::{prop, Rng};

/// Lengths straddling the lane counts of every kernel: empty, single,
/// one-under/on/one-over the 4- and 8-wide f32 strides, the 16-byte
/// packed-W4A8 stride, the 32-wide i8 stride, and long odd tails.
const LENS: [usize; 22] = [
    0, 1, 3, 4, 5, 7, 8, 9, 11, 16, 17, 19, 31, 32, 33, 35, 64, 67, 127, 128, 129, 259,
];

fn scalar_table() -> &'static isa::KernelTable {
    isa::table_for(Isa::Scalar).expect("scalar table is always available")
}

fn rand_i8_vec(rng: &mut Rng, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.next_u64() & 0xFF) as u8 as i8).collect()
}

/// Quantized Q15.17 values with occasional saturation-edge raws mixed
/// in, so the clamp/sat_add paths of the axpy kernels are exercised.
fn rand_fxp_vec(rng: &mut Rng, n: usize, edges: bool) -> Vec<Fxp32> {
    (0..n)
        .map(|_| {
            if edges && rng.gen_range(0, 16) == 0 {
                if rng.gen_range(0, 2) == 0 {
                    Fxp32::MAX
                } else {
                    Fxp32::MIN
                }
            } else {
                Fxp32::from_f32(rng.gen_range_f32(-4.0, 4.0))
            }
        })
        .collect()
}

#[test]
fn f32_dot_matches_scalar_within_tolerance() {
    let native = isa::active();
    let scalar = scalar_table();
    prop::check("f32 dot native ~= scalar (1e-5 rel)", 30, |rng, _| {
        for &n in &LENS {
            let a = rng.uniform_vec(n, 1.0);
            let b = rng.uniform_vec(n, 1.0);
            let got = (native.dot_f32)(&a, &b) as f64;
            let want = (scalar.dot_f32)(&a, &b) as f64;
            let tol = 1e-5 * want.abs().max(1.0);
            assert!(
                (got - want).abs() <= tol,
                "dot n={n}: native {got} vs scalar {want} (isa {})",
                native.name
            );
        }
    });
}

#[test]
fn f32_axpy_family_bit_identical_to_scalar() {
    let native = isa::active();
    let scalar = scalar_table();
    prop::check("f32 axpy/scale_axpy/scale bit-identical", 30, |rng, _| {
        for &n in &LENS {
            let a = rng.gen_range_f32(-2.0, 2.0);
            let x = rng.uniform_vec(n, 1.0);
            let y0 = rng.uniform_vec(n, 1.0);

            let (mut yn, mut ys) = (y0.clone(), y0.clone());
            (native.axpy_f32)(a, &mut yn, &x);
            (scalar.axpy_f32)(a, &mut ys, &x);
            assert_bits_eq(&yn, &ys, "axpy_f32", n);

            let (mut yn, mut ys) = (y0.clone(), y0.clone());
            (native.scale_axpy_f32)(a, &mut yn, &x);
            (scalar.scale_axpy_f32)(a, &mut ys, &x);
            assert_bits_eq(&yn, &ys, "scale_axpy_f32", n);

            let (mut yn, mut ys) = (y0.clone(), y0);
            (native.scale_f32)(a, &mut yn);
            (scalar.scale_f32)(a, &mut ys);
            assert_bits_eq(&yn, &ys, "scale_f32", n);
        }
    });
}

fn assert_bits_eq(got: &[f32], want: &[f32], kernel: &str, n: usize) {
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{kernel} n={n}: bit mismatch at {i} ({g} vs {w})"
        );
    }
}

#[test]
fn fxp_kernels_bit_exact_vs_scalar() {
    let native = isa::active();
    let scalar = scalar_table();
    prop::check("FXP32 dot/axpy/scale_axpy bit-exact", 30, |rng, _| {
        for &n in &LENS {
            let a = rand_fxp_vec(rng, n, true);
            let b = rand_fxp_vec(rng, n, true);
            assert_eq!(
                (native.dot_fxp_wide)(&a, &b),
                (scalar.dot_fxp_wide)(&a, &b),
                "dot_fxp_wide n={n}"
            );
            for s in [
                Fxp32::from_f32(rng.gen_range_f32(-2.0, 2.0)),
                Fxp32::MAX,
                Fxp32::MIN,
            ] {
                let (mut yn, mut ys) = (a.clone(), a.clone());
                (native.axpy_fxp)(s, &mut yn, &b);
                (scalar.axpy_fxp)(s, &mut ys, &b);
                assert_raw_eq(&yn, &ys, "axpy_fxp", n);

                let (mut yn, mut ys) = (a.clone(), a.clone());
                (native.scale_axpy_fxp)(s, &mut yn, &b);
                (scalar.scale_axpy_fxp)(s, &mut ys, &b);
                assert_raw_eq(&yn, &ys, "scale_axpy_fxp", n);
            }
        }
    });
}

fn assert_raw_eq(got: &[Fxp32], want: &[Fxp32], kernel: &str, n: usize) {
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.raw(), w.raw(), "{kernel} n={n}: raw mismatch at {i}");
    }
}

#[test]
fn integer_kernels_bit_exact_vs_scalar() {
    let native = isa::active();
    let scalar = scalar_table();
    prop::check("i8 dot + W4A8 column bit-exact", 30, |rng, _| {
        for &n in &LENS {
            let a = rand_i8_vec(rng, n);
            let b = rand_i8_vec(rng, n);
            assert_eq!((native.dot_i8)(&a, &b), (scalar.dot_i8)(&a, &b), "dot_i8 n={n}");

            // packed INT4 column at this din — both even and odd n hit
            // the half-byte tail handling
            let nibbles: Vec<i8> = (0..n).map(|_| rng.gen_range(0, 16) as i8 - 8).collect();
            let mut packed = vec![0u8; n.div_ceil(2)];
            pack_int4(&nibbles, &mut packed);
            assert_eq!(
                (native.w4a8_col)(&packed, n, &a),
                (scalar.w4a8_col)(&packed, n, &a),
                "w4a8_col din={n}"
            );
        }
    });
}

#[test]
fn gemv_matches_scalar_column_walk() {
    let scalar = scalar_table();
    prop::check("gemv_w4a8_raw_into == scalar column walk", 20, |rng, _| {
        let din = [1usize, 7, 16, 33, 64, 129][rng.gen_range(0, 6)];
        let dout = [1usize, 3, 17, 32][rng.gen_range(0, 4)];
        let w = Int4Matrix::quantize(&rng.uniform_vec(din * dout, 0.5), din, dout);
        let x = rng.uniform_vec(din, 1.0);
        let mut xq = vec![0i8; din];
        let xscale = quantize_int8_into(&x, &mut xq);

        let mut got = vec![0.0f32; dout];
        gemv_w4a8_raw_into(&xq, xscale, &w, &mut got);

        let stride = din.div_ceil(2);
        for j in 0..dout {
            let col = &w.packed[j * stride..(j + 1) * stride];
            let want = (scalar.w4a8_col)(col, din, &xq) as f32 * xscale * w.scales[j];
            assert_eq!(
                got[j].to_bits(),
                want.to_bits(),
                "gemv {din}x{dout} col {j}: {} vs {want}",
                got[j]
            );
        }
    });
}

#[test]
fn gemm_cross_panel_bit_identical_to_per_lane_gemv() {
    // din spans two KC panels with an odd tail, so the blocked GEMM's
    // partial-accumulator handoff between panels is on the line
    let din = GEMM_KC + 37;
    let dout = 48usize;
    prop::check("blocked GEMM == per-lane GEMV across panels", 5, |rng, _| {
        let w = Int4Matrix::quantize(&rng.uniform_vec(din * dout, 0.5), din, dout);
        let b = 1 + rng.gen_range(0, 5);
        let mut qrows = vec![0i8; b * din];
        let mut scales = vec![0.0f32; b];
        for i in 0..b {
            let x = rng.uniform_vec(din, 1.0);
            scales[i] = quantize_int8_into(&x, &mut qrows[i * din..(i + 1) * din]);
        }
        let mut got = vec![0.0f32; b * dout];
        gemm_w4a8_raw_into(&qrows, &scales, &w, &mut got);
        let mut want = vec![0.0f32; dout];
        for i in 0..b {
            gemv_w4a8_raw_into(&qrows[i * din..(i + 1) * din], scales[i], &w, &mut want);
            for j in 0..dout {
                assert_eq!(
                    got[i * dout + j].to_bits(),
                    want[j].to_bits(),
                    "lane {i} col {j} (b={b})"
                );
            }
        }
    });
}

#[test]
fn dispatch_is_selected_once_and_env_parse_is_strict() {
    // active() must resolve to one of the constructable tables and
    // never re-detect per call
    let t = isa::active();
    assert!(isa::table_for(t.isa).is_some(), "active table {} not constructable", t.name);
    let before = isa::detections();
    for _ in 0..64 {
        let _ = isa::active();
        let _ = isa::active_name();
    }
    assert_eq!(isa::detections(), before, "active() re-ran ISA detection");
    assert!(Isa::parse("avx512").is_none());
    assert!(Isa::parse("AVX2").is_none(), "ISA names are case-sensitive");
}
