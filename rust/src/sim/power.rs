//! Power & efficiency model — Tables III/IV and Fig. 8(b).
//!
//! §V: "normalized power consumption of the SwiftKV-MHA FPGA chip is
//! 18.3 W, with HBM power consumption of approximately 15.5 W" → 33.8 W
//! system (Table III). Efficiency metrics: token/J = speed / system
//! power; GOPS/W (Table IV convention, chip power).
//!
//! Chip power is a first-order activity model over the resource estimate:
//! static + per-DSP + per-LUT + per-BRAM dynamic at the given clock,
//! fitted to the paper's 18.3 W at the default configuration.

use super::resources::{estimate, ResourceReport};
use super::ArchConfig;

/// Power estimate breakdown (watts).
#[derive(Debug, Clone)]
pub struct PowerReport {
    pub static_w: f64,
    pub dsp_w: f64,
    pub logic_w: f64,
    pub bram_w: f64,
    pub hbm_w: f64,
}

impl PowerReport {
    pub fn chip_w(&self) -> f64 {
        self.static_w + self.dsp_w + self.logic_w + self.bram_w
    }

    pub fn system_w(&self) -> f64 {
        self.chip_w() + self.hbm_w
    }
}

/// Per-unit dynamic power constants at 225 MHz (fitted to §V's 18.3 W
/// chip + 15.5 W HBM at full streaming).
const STATIC_W: f64 = 3.2;
const DSP_MW: f64 = 1.5;
const LUT_UW: f64 = 7.0;
const BRAM_MW: f64 = 8.0;
const HBM_W_FULL: f64 = 15.5;

/// Estimate power for an architecture (chip scales with clock and
/// resources; HBM with achieved bandwidth utilization).
pub fn power(arch: &ArchConfig, hbm_utilization: f64) -> PowerReport {
    let r: ResourceReport = estimate(arch);
    let t = r.total();
    let f_scale = arch.clock_mhz / 225.0;
    PowerReport {
        static_w: STATIC_W,
        dsp_w: t.dsp as f64 * DSP_MW / 1e3 * f_scale,
        logic_w: (t.lut + t.ff / 2) as f64 * LUT_UW / 1e6 * f_scale,
        bram_w: t.bram as f64 * BRAM_MW / 1e3 * f_scale,
        hbm_w: HBM_W_FULL * hbm_utilization.clamp(0.0, 1.0),
    }
}

/// Tokens per joule at a generation speed (Table III's token/J column).
pub fn tokens_per_joule(tokens_per_s: f64, system_w: f64) -> f64 {
    tokens_per_s / system_w
}

/// GOPS per watt (Table IV's efficiency column, chip power convention).
pub fn gops_per_watt(gops: f64, chip_w: f64) -> f64 {
    gops / chip_w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chip_power_matches_paper() {
        let p = power(&ArchConfig::default(), 1.0);
        assert!(
            (p.chip_w() - 18.3).abs() < 0.8,
            "chip power {:.1} W vs paper 18.3 W",
            p.chip_w()
        );
    }

    #[test]
    fn system_power_matches_table3() {
        let p = power(&ArchConfig::default(), 1.0);
        assert!(
            (p.system_w() - 33.8).abs() < 1.0,
            "system {:.1} W vs paper 33.8 W",
            p.system_w()
        );
    }

    /// Table III: 81.5 token/s at 33.8 W → 2.41 token/J.
    #[test]
    fn tokens_per_joule_llama2() {
        let tpj = tokens_per_joule(81.5, 33.8);
        assert!((tpj - 2.41).abs() < 0.02, "{tpj:.2}");
    }

    /// Table IV: 1100.3 GOPS / 18.3 W = 60.12 GOPS/W.
    #[test]
    fn gops_per_watt_table4() {
        let e = gops_per_watt(1100.3, 18.3);
        assert!((e - 60.12).abs() < 0.2, "{e:.2}");
    }

    #[test]
    fn hbm_power_scales_with_utilization() {
        let idle = power(&ArchConfig::default(), 0.0);
        let full = power(&ArchConfig::default(), 1.0);
        assert!(idle.hbm_w < 0.1);
        assert!((full.hbm_w - 15.5).abs() < 1e-9);
        assert_eq!(idle.chip_w(), full.chip_w());
    }

    #[test]
    fn power_scales_with_clock() {
        let slow = power(
            &ArchConfig {
                clock_mhz: 112.5,
                ..ArchConfig::default()
            },
            1.0,
        );
        let fast = power(&ArchConfig::default(), 1.0);
        assert!(slow.chip_w() < fast.chip_w());
        assert!(slow.chip_w() > fast.chip_w() / 2.0); // static floor
    }
}
