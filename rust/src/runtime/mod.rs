//! PJRT runtime — loads the AOT artifacts and executes them from the
//! serving hot path. Python never runs here: the HLO text produced once by
//! `python/compile/aot.py` is parsed, compiled and executed through the
//! `xla` crate's PJRT CPU client.
//!
//! The engine is gated behind the off-by-default `pjrt` feature: the
//! `xla` crate closure is heavyweight and only present where it has been
//! vendored (see `Cargo.toml`). The default build still exposes the
//! artifact-path helpers so artifact-optional callers compile unchanged;
//! serving without the feature goes through
//! [`crate::coordinator::CpuServer`].
//!
//! `engine::Engine` owns the client, the compiled decode-step
//! executables (one per batch variant) and the resident weight literals;
//! `engine::BatchState` carries a batch's KV caches and RoPE recurrence
//! state between steps.

#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(feature = "pjrt")]
pub use engine::{BatchState, Engine};

/// Default artifacts directory (relative to the crate root).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the AOT artifacts have been built.
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}
