//! LUT-based exponential — Eqs. (9)–(10) of the paper.
//!
//! SwiftKV's rescale factors `α = exp(μ−s)` and `β = exp(s−μ)` always lie
//! in `(0, 1]` (the argument is ≤ 0), so the hardware computes
//!
//! ```text
//! exp(x) = 2^{x·log₂e} = 2^{n+f},   n ∈ Z⁻, f ∈ (−1, 0]
//! ```
//!
//! where `2^n` is a bit shift and `2^f` comes from a 32-entry lookup table
//! with linear (secant) interpolation: `f = f₁ + f₂` with `f₁` the 5 most
//! significant fractional bits (LUT index `i ∈ {0..31}`) and `f₂` the
//! remaining 12 bits; `LUT[i] = 2^{−i/32}` and
//!
//! ```text
//! 2^f = δᵢ·f₂ + LUT[i]                                   (Eq. 10)
//! ```
//!
//! With secant slopes the worst-case relative interpolation error of
//! `2^{−x}` over a 1/32 interval is `(ln2/32)²/8 ≈ 5.865e-5 = 0.00586 %` —
//! exactly the figure the paper reports (§V). The unit tests and exhibit
//! E8 assert this.

use super::q1517::{Fxp32, FRAC_BITS};

/// Internal LUT precision: Q2.30 (values in (0.5, 1] need 1 integer bit;
/// 30 fractional bits keep quantization noise ~1e-9, far below the
/// 5.9e-5 interpolation error so the paper's error figure is preserved).
const LUT_FRAC: u32 = 30;
/// Index bits (f₁): 32-entry table.
const INDEX_BITS: u32 = 5;
/// Remaining fractional bits (f₂) used for interpolation.
const F2_BITS: u32 = FRAC_BITS - INDEX_BITS; // 12

/// The 5-bit LUT + secant-slope exponential unit of the SwiftKV core.
///
/// One instance models one hardware exp unit; construction precomputes the
/// ROM contents exactly as synthesis would.
#[derive(Debug, Clone)]
pub struct Exp2Lut {
    /// `LUT[i] = round(2^{−i/32} · 2^30)` for `i ∈ 0..=32` (entry 32 = 0.5
    /// exists only to form the last secant slope).
    lut: [i64; 33],
    /// Secant differences `LUT[i+1] − LUT[i]` (negative), Q2.30.
    diff: [i64; 32],
    /// `log₂e` in Q15.17.
    log2e: i64,
}

impl Default for Exp2Lut {
    fn default() -> Self {
        Self::new()
    }
}

impl Exp2Lut {
    /// Build the ROM: `LUT[i] = 2^{−i/32}` in Q2.30 plus secant slopes.
    pub fn new() -> Self {
        let mut lut = [0i64; 33];
        for (i, e) in lut.iter_mut().enumerate() {
            *e = ((-(i as f64) / 32.0).exp2() * (1i64 << LUT_FRAC) as f64).round() as i64;
        }
        let mut diff = [0i64; 32];
        for i in 0..32 {
            diff[i] = lut[i + 1] - lut[i];
        }
        let log2e = (std::f64::consts::LOG2_E * (1i64 << FRAC_BITS) as f64).round() as i64;
        Exp2Lut { lut, diff, log2e }
    }

    /// `2^f` for `f ∈ (−1, 0]` given as the magnitude's 17 fractional bits
    /// (`frac17 = −f · 2^17`). Returns Q2.30. This is Eq. (10) verbatim:
    /// top 5 bits index the LUT, bottom 12 bits drive the interpolation.
    #[inline]
    pub fn pow2_neg_frac_q30(&self, frac17: u32) -> i64 {
        debug_assert!(frac17 < (1 << FRAC_BITS));
        let i = (frac17 >> F2_BITS) as usize; // f₁: 5 MSBs
        let f2 = (frac17 & ((1 << F2_BITS) - 1)) as i64; // f₂: 12 LSBs
        // δᵢ·f₂ + LUT[i]; δᵢ is diff[i]/2^12, folded into the shift.
        self.lut[i] + ((self.diff[i] * f2) >> F2_BITS)
    }

    /// `2^f` for `f ∈ (−1, 0]`, Q15.17 in/out (test/diagnostic entry).
    #[inline]
    pub fn pow2_neg_frac(&self, f: Fxp32) -> Fxp32 {
        debug_assert!(f.raw() <= 0 && f.raw() > -(1 << FRAC_BITS));
        let frac17 = (-f.raw()) as u32;
        let q30 = self.pow2_neg_frac_q30(frac17);
        Fxp32::from_raw(q30_to_q17(q30))
    }

    /// `exp(x)` for `x ≤ 0` — the full Eq. (9) datapath:
    /// `u = x·log₂e`, split into integer `n` (bit shift) and fraction `f`
    /// (LUT + interpolation). Arguments > 0 are clamped to 0 (the SwiftKV
    /// recurrence never produces them; hardware would flag this).
    #[inline]
    pub fn exp_neg(&self, x: Fxp32) -> Fxp32 {
        if x.raw() >= 0 {
            return Fxp32::ONE;
        }
        // u = x·log2e in Q15.17, computed on the shared multiplier:
        // (Q17 × Q17) >> 17 with round-to-nearest.
        let wide = x.raw() as i64 * self.log2e;
        let u = -((wide + (1 << (FRAC_BITS - 1))) >> FRAC_BITS); // magnitude, ≥ 0
        let n = (u >> FRAC_BITS) as u32; // integer part → shift amount
        let frac17 = (u & ((1 << FRAC_BITS) - 1)) as u32;
        if n >= 31 {
            return Fxp32::ZERO; // underflow: exp(x) < 2^-31
        }
        let q30 = self.pow2_neg_frac_q30(frac17);
        // combine: (2^f) >> n, then Q2.30 → Q15.17 with rounding
        Fxp32::from_raw(q30_to_q17(q30 >> n))
    }

    /// Maximum relative error of the `2^f` approximation over `(−1, 0]`,
    /// swept at every representable Q15.17 point (exhibit **E8**).
    pub fn max_relative_error(&self) -> f64 {
        let mut max_rel = 0.0f64;
        for frac17 in 0..(1u32 << FRAC_BITS) {
            let approx = self.pow2_neg_frac_q30(frac17) as f64 / (1i64 << LUT_FRAC) as f64;
            let exact = (-(frac17 as f64) / (1u32 << FRAC_BITS) as f64).exp2();
            let rel = ((approx - exact) / exact).abs();
            if rel > max_rel {
                max_rel = rel;
            }
        }
        max_rel
    }
}

/// Ablation helper: max relative error of a `bits`-bit LUT + secant
/// interpolation over (−1, 0] (the paper chose 5 bits; §III). Pure f64
/// construction — used by the `ablation_lut` example and DESIGN.md's
/// design-choice discussion. Interpolation error scales as `h²/8·(ln2)²`
/// with `h = 2^-bits`, so each extra index bit buys ~4×.
pub fn lut_ablation_error(bits: u32) -> f64 {
    assert!((1..=10).contains(&bits));
    let entries = 1usize << bits;
    let lut: Vec<f64> = (0..=entries)
        .map(|i| (-(i as f64) / entries as f64).exp2())
        .collect();
    let mut max_rel = 0.0f64;
    // sweep at fine resolution between knots
    let steps = 1usize << 17;
    for j in 0..steps {
        let f = j as f64 / steps as f64; // magnitude of the fraction
        let idx = ((f * entries as f64) as usize).min(entries - 1);
        let frac = f * entries as f64 - idx as f64;
        let approx = lut[idx] + (lut[idx + 1] - lut[idx]) * frac;
        let exact = (-f).exp2();
        let rel = ((approx - exact) / exact).abs();
        if rel > max_rel {
            max_rel = rel;
        }
    }
    max_rel
}

/// Q2.30 → Q15.17 with round-to-nearest.
#[inline]
fn q30_to_q17(q30: i64) -> i32 {
    ((q30 + (1 << (LUT_FRAC - FRAC_BITS - 1))) >> (LUT_FRAC - FRAC_BITS)) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_endpoints() {
        let lut = Exp2Lut::new();
        // 2^0 = 1
        assert_eq!(lut.pow2_neg_frac(Fxp32::ZERO), Fxp32::ONE);
        // 2^-0.5 = 0.70710678
        let half = lut.pow2_neg_frac(Fxp32::from_f64(-0.5)).to_f64();
        assert!((half - 0.5f64.sqrt()).abs() < 1e-4, "{half}");
    }

    #[test]
    fn exp_matches_float_reference() {
        let lut = Exp2Lut::new();
        for i in 0..=1000 {
            let x = -10.0 * i as f64 / 1000.0;
            let got = lut.exp_neg(Fxp32::from_f64(x)).to_f64();
            let want = x.exp();
            assert!(
                (got - want).abs() < 1e-4 + want * 1e-4,
                "exp({x}): got {got}, want {want}"
            );
        }
    }

    #[test]
    fn exp_zero_and_positive_clamp() {
        let lut = Exp2Lut::new();
        assert_eq!(lut.exp_neg(Fxp32::ZERO), Fxp32::ONE);
        assert_eq!(lut.exp_neg(Fxp32::from_f64(3.0)), Fxp32::ONE);
    }

    #[test]
    fn exp_underflows_to_zero() {
        let lut = Exp2Lut::new();
        assert_eq!(lut.exp_neg(Fxp32::from_f64(-30.0)), Fxp32::ZERO);
        assert_eq!(lut.exp_neg(Fxp32::from_f64(-1000.0)), Fxp32::ZERO);
    }

    #[test]
    fn exp_output_in_unit_interval() {
        // α, β ∈ (0, 1] — the property §III relies on for fixed point.
        let lut = Exp2Lut::new();
        for i in 0..2000 {
            let x = -20.0 * i as f64 / 2000.0;
            let y = lut.exp_neg(Fxp32::from_f64(x));
            assert!(y.raw() >= 0 && y <= Fxp32::ONE, "exp({x}) = {y}");
        }
    }

    #[test]
    fn exp_monotonic_nonincreasing_in_magnitude() {
        let lut = Exp2Lut::new();
        let mut prev = Fxp32::ONE;
        for i in 0..=4000 {
            let x = -8.0 * i as f64 / 4000.0;
            let y = lut.exp_neg(Fxp32::from_f64(x));
            assert!(y <= prev, "non-monotonic at x={x}");
            prev = y;
        }
    }

    /// Ablation: the 5-bit choice is the smallest LUT meeting the 1e-5
    /// FXP32 resolution target; 4 bits misses it by 4×, 6 bits wastes ROM.
    #[test]
    fn lut_width_ablation() {
        let e4 = super::lut_ablation_error(4);
        let e5 = super::lut_ablation_error(5);
        let e6 = super::lut_ablation_error(6);
        assert!(e4 > 2e-4 && e4 < 3e-4, "{e4}");
        assert!(e5 > 5e-5 && e5 < 7e-5, "{e5}"); // the paper's 0.00586 %
        assert!(e6 > 1.2e-5 && e6 < 2e-5, "{e6}");
        // quadratic scaling: each bit ≈ 4×
        assert!((e4 / e5 - 4.0).abs() < 0.5);
        assert!((e5 / e6 - 4.0).abs() < 0.5);
    }

    /// Exhibit E8: the paper reports a max relative error of 0.00586 %
    /// for the LUT+interpolation over (−1, 0].
    #[test]
    fn max_relative_error_matches_paper() {
        let lut = Exp2Lut::new();
        let err = lut.max_relative_error();
        // (ln2/32)²/8 = 5.865e-5 → 0.005865 %
        assert!(err < 6.0e-5, "err = {err}");
        assert!(err > 5.5e-5, "err = {err} suspiciously low — wrong sweep?");
        let pct = err * 100.0;
        assert!(
            (pct - 0.00586).abs() < 0.0002,
            "paper: 0.00586 %, measured {pct:.5} %"
        );
    }
}
