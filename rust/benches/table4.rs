//! Bench: regenerate Table IV (GOPS / GOPS/W comparison) and Table II
//! (resource utilization), plus the exp-LUT error exhibit.

use swiftkv::report;
use swiftkv::sim::{resources, ArchConfig};
use swiftkv::util::bench::Bencher;

fn main() {
    let arch = ArchConfig::default();
    println!("{}", report::table2(&arch));
    println!("{}", report::table4(&arch));
    println!("{}", report::exp_lut_error());

    let mut b = Bencher::new(100, 400);
    b.bench("sim/resource_estimate", || resources::estimate(&arch));
    b.bench("fxp/exp_lut_error_sweep(131k points)", || {
        swiftkv::fxp::Exp2Lut::new().max_relative_error()
    });
}
