"""Layer-2: the JAX decode model that the AOT path lowers to HLO.

A small multi-head decoder ("tiny" config by default) whose per-layer
dataflow mirrors the SwiftKV-MHA pipeline of §IV-A exactly:

    RMSNorm -> INT8 quant -> W4A8 GEMV (Q,K,V)        [Processor Array]
    -> decoder-RoPE on the new token's q,k (Eq. 11)   [RoPE unit]
    -> KV-cache append -> single-pass SwiftKV attention [SKV units]
    -> INT8 quant -> W4A8 GEMV (O)                     [Processor Array]
    -> residual; RMSNorm -> quant -> gate/up GEMV ->
       SiLU * Hadamard -> quant -> down GEMV -> residual   [SFU + Array]

All three Pallas kernels (attention, RoPE, GEMV) lower into the same HLO
module; Python never runs at serving time. Weights are *runtime inputs*
(not baked constants) so the HLO stays small; the Rust runtime feeds them
once from ``artifacts/weights.bin``.

The fixed-point (FXP32/LUT-exp) datapath is modelled bit-exactly on the
Rust side; here attention runs in f32, which is the "desktop" numerics the
paper compares its accelerator against in Table I.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.gemv import gemv_w4a8_batched
from .kernels.rope import rope_decode_step
from .kernels.swiftkv import swiftkv_attention


@dataclasses.dataclass(frozen=True)
class TinyConfig:
    """A ~3.4M-parameter decoder shaped like the paper's targets
    (pre-norm, RoPE, SwiGLU) but laptop-sized."""

    vocab: int = 512
    d_model: int = 256
    n_heads: int = 8
    # KV heads (GQA/MQA when < n_heads): the K/V projections and caches
    # shrink to n_kv_heads * d_head, and each KV head serves its whole
    # group of n_heads // n_kv_heads query heads. The manifest carries
    # it explicitly because the Rust loader (TinyModel::load) validates
    # K/V projection widths against it.
    n_kv_heads: int = 8
    d_head: int = 32
    n_layers: int = 4
    d_ffn: int = 768
    n_ctx: int = 256          # KV-cache capacity
    rope_base: float = 10000.0
    block_k: int = 64         # attention kernel KV tile

    @property
    def heads_dim(self) -> int:
        return self.n_heads * self.d_head


# Deterministic parameter order used for both the HLO input signature and
# the weights.bin layout. Each entry is (name, kind) where kind determines
# shape/dtype; see param_specs().
def param_names(cfg: TinyConfig) -> List[str]:
    names = ["embedding"]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        names += [p + "attn_norm"]
        for w in ("wq", "wk", "wv", "wo"):
            names += [p + w + ".q", p + w + ".scale"]
        names += [p + "mlp_norm"]
        for w in ("w_gate", "w_up", "w_down"):
            names += [p + w + ".q", p + w + ".scale"]
    names += ["final_norm", "lm_head.q", "lm_head.scale"]
    return names


def param_specs(cfg: TinyConfig) -> List[Tuple[str, Tuple[int, ...], str]]:
    """(name, shape, dtype) for every parameter, in signature order."""
    d, f, v = cfg.d_model, cfg.d_ffn, cfg.vocab
    d_kv = cfg.n_kv_heads * cfg.d_head  # GQA/MQA: K/V widths shrink

    def mat(name, din, dout):
        return [(name + ".q", (din, dout), "int8"),
                (name + ".scale", (dout,), "float32")]

    specs: List[Tuple[str, Tuple[int, ...], str]] = [
        ("embedding", (v, d), "float32")]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        specs += [(p + "attn_norm", (d,), "float32")]
        specs += mat(p + "wq", d, d) + mat(p + "wk", d, d_kv) + \
            mat(p + "wv", d, d_kv) + mat(p + "wo", d, d)
        specs += [(p + "mlp_norm", (d,), "float32")]
        specs += mat(p + "w_gate", d, f) + mat(p + "w_up", d, f) + \
            mat(p + "w_down", f, d)
    specs += [("final_norm", (d,), "float32")]
    specs += mat("lm_head", d, v)
    return specs


def init_params(cfg: TinyConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Seeded synthetic weights, quantized W4A8 at build time."""
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jax.Array] = {}

    def take():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    def qmat(name, din, dout, std):
        w = jax.random.normal(take(), (din, dout), jnp.float32) * std
        wq, ws = ref.quantize_int4(w)
        params[name + ".q"] = wq
        params[name + ".scale"] = ws

    d, f, v = cfg.d_model, cfg.d_ffn, cfg.vocab
    d_kv = cfg.n_kv_heads * cfg.d_head
    std = 0.6 / np.sqrt(d)
    params["embedding"] = jax.random.normal(take(), (v, d), jnp.float32) * 0.6
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        params[p + "attn_norm"] = jnp.ones((d,), jnp.float32)
        for w, dout in (("wq", d), ("wk", d_kv), ("wv", d_kv), ("wo", d)):
            qmat(p + w, d, dout, std)
        params[p + "mlp_norm"] = jnp.ones((d,), jnp.float32)
        qmat(p + "w_gate", d, f, std)
        qmat(p + "w_up", d, f, std)
        qmat(p + "w_down", f, d, 0.6 / np.sqrt(f))
    params["final_norm"] = jnp.ones((d,), jnp.float32)
    qmat("lm_head", d, v, std)
    return params


def rope_constants(cfg: TinyConfig):
    """a_i = cos(theta_i), b_i = sin(theta_i) — the SKV-unit constants."""
    omega = jnp.asarray(ref.rope_freqs(cfg.d_head, cfg.rope_base), jnp.float32)
    return jnp.cos(omega), jnp.sin(omega)


def init_state(cfg: TinyConfig, batch: int):
    """Fresh decode state: zero KV caches and the (cos, sin) recurrence
    seeds. The cache holds cos/sin for the *last processed* position, so
    the pos=0 seed is cos(-theta)=a, sin(-theta)=-b (one step before 0).
    GQA/MQA caches hold n_kv_heads rows per token."""
    a, b = rope_constants(cfg)
    kc = jnp.zeros((batch, cfg.n_layers, cfg.n_kv_heads, cfg.n_ctx, cfg.d_head),
                   jnp.float32)
    vc = jnp.zeros_like(kc)
    cos = jnp.broadcast_to(a, (batch, cfg.d_head // 2))
    sin = jnp.broadcast_to(-b, (batch, cfg.d_head // 2))
    return kc, vc, cos, sin


def rms_norm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def _quant_rows(x: jax.Array):
    """Per-row symmetric INT8 quantization (SFU cast), batched."""
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-8)
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def _gemv(x: jax.Array, params, name: str) -> jax.Array:
    """Quantize activations, run the W4A8 GEMV kernel, return f32 [B, dout]."""
    xq, xs = _quant_rows(x)
    return gemv_w4a8_batched(xq, xs, params[name + ".q"], params[name + ".scale"])


def decode_step(params: Dict[str, jax.Array], cfg: TinyConfig,
                tokens: jax.Array, pos: jax.Array,
                kc: jax.Array, vc: jax.Array,
                cos: jax.Array, sin: jax.Array):
    """One decode step for a batch of sequences.

    tokens: [B] int32; pos: [B] int32 (0-based position of this token);
    kc, vc: [B, L, H_kv, N, dh] (n_kv_heads rows under GQA/MQA);
    cos, sin: [B, dh/2] RoPE recurrence state.
    Returns (logits [B, vocab], kc', vc', cos', sin').
    """
    bsz = tokens.shape[0]
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if hkv <= 0 or h % hkv != 0:
        raise ValueError(
            f"n_heads ({h}) must be a positive multiple of n_kv_heads ({hkv})")
    group = h // hkv
    a_const, b_const = rope_constants(cfg)

    x = params["embedding"][tokens]                     # [B, d]
    lens = pos + 1                                      # valid cache rows
    row_lens = jnp.repeat(lens, h)                      # [B*H]

    # Continuous batching: a lane starting a fresh sequence (pos == 0)
    # resets its RoPE recurrence to the pre-position-0 seed, regardless of
    # what an earlier occupant of the lane left behind.
    restart = (pos == 0)[:, None]
    cos = jnp.where(restart, a_const[None, :], cos)
    sin = jnp.where(restart, -b_const[None, :], sin)

    cos_next, sin_next = cos, sin
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        xn = rms_norm(x, params[p + "attn_norm"])
        q = _gemv(xn, params, p + "wq").reshape(bsz * h, dh)
        k = _gemv(xn, params, p + "wk").reshape(bsz * hkv, dh)
        v = _gemv(xn, params, p + "wv").reshape(bsz * hkv, dh)

        # decoder-specialized RoPE: rotate only the new token's q, k and
        # advance the cached (cos, sin) one position (Eq. 11). Under
        # GQA/MQA q and k have different row counts, so each rotates
        # through its own kernel call off the same cached (cos, sin);
        # both advance the recurrence identically and the q call's
        # output is kept.
        if hkv == h:
            q, k, cos_next, sin_next = rope_decode_step(
                q, k, cos, sin, a_const, b_const, heads_per_seq=h)
        else:
            q, _, cos_next, sin_next = rope_decode_step(
                q, q, cos, sin, a_const, b_const, heads_per_seq=h)
            _, k, _, _ = rope_decode_step(
                k, k, cos, sin, a_const, b_const, heads_per_seq=hkv)

        # append the (already position-encoded) k, v to the cache
        k_bh = k.reshape(bsz, hkv, dh)
        v_bh = v.reshape(bsz, hkv, dh)
        upd = jax.vmap(
            lambda c, kv, s: jax.lax.dynamic_update_slice(c, kv[:, None, :],
                                                          (0, s, 0)))
        kc = kc.at[:, l].set(upd(kc[:, l], k_bh, pos))
        vc = vc.at[:, l].set(upd(vc[:, l], v_bh, pos))

        # single-pass SwiftKV attention over the row-batched cache;
        # each KV head's rows are repeated for its whole query group
        # (consecutive query heads share a KV head, the Rust layout)
        k_rows = jnp.repeat(kc[:, l], group, axis=1) \
            .reshape(bsz * h, cfg.n_ctx, dh)
        v_rows = jnp.repeat(vc[:, l], group, axis=1) \
            .reshape(bsz * h, cfg.n_ctx, dh)
        attn = swiftkv_attention(q, k_rows, v_rows, row_lens,
                                 block_k=cfg.block_k)   # [B*H, dh]
        attn = attn.reshape(bsz, h * dh)
        x = x + _gemv(attn, params, p + "wo")

        # SwiGLU MLP (SiLU and Hadamard run in the SFU, f32)
        xn = rms_norm(x, params[p + "mlp_norm"])
        gate = _gemv(xn, params, p + "w_gate")
        up = _gemv(xn, params, p + "w_up")
        act = jax.nn.silu(gate) * up
        x = x + _gemv(act, params, p + "w_down")

    xn = rms_norm(x, params["final_norm"])
    logits = _gemv(xn, params, "lm_head")               # [B, vocab]
    return logits, kc, vc, cos_next, sin_next


def decode_step_flat(cfg: TinyConfig, tokens, pos, kc, vc, cos, sin,
                     *flat_params):
    """Flattened-signature wrapper used for AOT lowering: parameters arrive
    as positional arrays in ``param_specs`` order."""
    names = [s[0] for s in param_specs(cfg)]
    params = dict(zip(names, flat_params))
    return decode_step(params, cfg, tokens, pos, kc, vc, cos, sin)


def greedy_generate(params: Dict[str, jax.Array], cfg: TinyConfig,
                    prompt: np.ndarray, steps: int):
    """Reference greedy decode loop (used by tests to cross-check the Rust
    serving path). prompt: [T] int32. Returns generated ids [steps]."""
    kc, vc, cos, sin = init_state(cfg, 1)
    tok = jnp.asarray(prompt[:1], jnp.int32)
    out = []
    t = 0
    for t_idx in range(len(prompt) + steps - 1):
        pos = jnp.asarray([t_idx], jnp.int32)
        logits, kc, vc, cos, sin = decode_step(
            params, cfg, tok, pos, kc, vc, cos, sin)
        if t_idx + 1 < len(prompt):
            tok = jnp.asarray(prompt[t_idx + 1:t_idx + 2], jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(int(tok[0]))
        t = t_idx
    return np.asarray(out, np.int32)
