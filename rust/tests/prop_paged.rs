//! Property tests: the paged KV sweeps (`extend_paged` over
//! `BlockPool`/`BlockTable`) versus the contiguous token-major path,
//! swept over GQA/MQA/MHA shapes, block lengths {1, 3, 16} (so ragged
//! last blocks are routine), chunked extends, and pools whose blocks
//! have been scrambled by lane recycling. The storage contract is the
//! only thing that changed, so the bar is strict: the f32 paged sweep
//! must be **bit-identical** to the contiguous sweep (same rows, same
//! op order — well inside the 1e-5 acceptance bound), and the Q15.17
//! sweep **bit-exact** on raw bits.

use swiftkv::fxp::{vector, Exp2Lut, Fxp32};
use swiftkv::kernels::{BlockPool, BlockTable, FxpMhaSwiftKv, MhaSwiftKv};
use swiftkv::util::{prop, Rng};

/// (n_heads, n_kv_heads): MQA, GQA group factors, `group == 1` MHA.
const GROUPS: [(usize, usize); 6] = [(1, 1), (2, 1), (4, 2), (6, 3), (8, 2), (8, 8)];
/// Head dims off and on the SIMD unroll width.
const DIMS: [usize; 4] = [3, 5, 16, 33];
/// Cache lengths, including several that leave ragged last blocks.
const LENS: [usize; 5] = [1, 2, 5, 17, 40];
/// Block lengths under test: degenerate (1 row/block), odd, default-ish.
const BLOCK_LENS: [usize; 3] = [1, 3, 16];

struct PagedCase {
    h: usize,
    hkv: usize,
    d: usize,
    len: usize,
    block_len: usize,
    q: Vec<f32>,
    /// Contiguous token-major interleaved `[len][hkv * d]` references.
    k: Vec<f32>,
    v: Vec<f32>,
    pool: BlockPool,
}

impl PagedCase {
    fn random(rng: &mut Rng, scale: f32) -> PagedCase {
        let (h, hkv) = GROUPS[rng.gen_range(0, GROUPS.len())];
        let d = DIMS[rng.gen_range(0, DIMS.len())];
        let len = LENS[rng.gen_range(0, LENS.len())];
        let block_len = BLOCK_LENS[rng.gen_range(0, BLOCK_LENS.len())];
        let row = hkv * d;
        PagedCase {
            h,
            hkv,
            d,
            len,
            block_len,
            q: rng.uniform_vec(h * d, scale),
            k: rng.uniform_vec(len * row, scale),
            v: rng.uniform_vec(len * row, scale),
            pool: BlockPool::new(len.div_ceil(block_len) + 1, block_len, row),
        }
    }

    /// Check a table out of the pool and fill it (f32 + Q15.17 mirror)
    /// from the contiguous reference arrays.
    fn build_table(&self) -> BlockTable {
        let row = self.hkv * self.d;
        let mut table = BlockTable::new(&self.pool, self.len);
        table.ensure_tokens(&self.pool, self.len);
        for t in 0..self.len {
            table
                .k_row_mut(t)
                .copy_from_slice(&self.k[t * row..(t + 1) * row]);
            table
                .v_row_mut(t)
                .copy_from_slice(&self.v[t * row..(t + 1) * row]);
            table.quantize_row(t);
        }
        table
    }
}

#[test]
fn prop_paged_f32_bit_identical_to_contiguous() {
    prop::check("paged f32 sweep == contiguous sweep (bit)", 40, |rng, _| {
        let case = PagedCase::random(rng, 1.0);
        let (h, hkv, d, len, bl) = (case.h, case.hkv, case.d, case.len, case.block_len);
        let scale = 1.0 / (d as f32).sqrt();
        let mut table = case.build_table();

        let mut contiguous = MhaSwiftKv::new_grouped(h, hkv, d);
        let mut a = vec![0.0f32; h * d];
        contiguous.attend(&case.q, &case.k, &case.v, len, scale, &mut a);

        let mut paged = MhaSwiftKv::new_grouped(h, hkv, d);
        paged.extend_paged(&case.q, &table, 0, len, scale);
        let mut b = vec![0.0f32; h * d];
        paged.finalize_into(&mut b);

        assert_eq!(a, b, "h={h} hkv={hkv} d={d} len={len} bl={bl}");
        table.release_into(&case.pool);
    });
}

#[test]
fn prop_paged_fxp_bit_exact_vs_contiguous() {
    prop::check("paged Q15.17 sweep == contiguous (raw bits)", 30, |rng, _| {
        let case = PagedCase::random(rng, 1.0);
        let (h, hkv, d, len, bl) = (case.h, case.hkv, case.d, case.len, case.block_len);
        let lut = Exp2Lut::new();
        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let mut table = case.build_table();

        let qq = vector::quantize(&case.q);
        let kq = vector::quantize(&case.k);
        let vq = vector::quantize(&case.v);
        let mut contiguous = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut a = vec![Fxp32::ZERO; h * d];
        contiguous.attend(&lut, &qq, &kq, &vq, len, scale, &mut a);

        let mut paged = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        paged.extend_paged(&lut, &qq, &table, 0, len, scale);
        let mut b = vec![Fxp32::ZERO; h * d];
        paged.finalize_into(&mut b);

        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                x.raw(),
                y.raw(),
                "h={h} hkv={hkv} d={d} len={len} bl={bl} flat-dim={i}: raw bits diverged"
            );
        }
        table.release_into(&case.pool);
    });
}

#[test]
fn prop_paged_chunked_extend_equals_one_shot() {
    prop::check("paged chunked extend == one-shot paged sweep", 30, |rng, _| {
        let case = PagedCase::random(rng, 1.0);
        let (h, hkv, d, len) = (case.h, case.hkv, case.d, case.len);
        let scale = 1.0 / (d as f32).sqrt();
        // cut ∈ [0, len]: 0 exercises an empty first extend; cuts need
        // not align with block boundaries
        let cut = rng.gen_range(0, len + 1);
        let mut table = case.build_table();

        let mut one = MhaSwiftKv::new_grouped(h, hkv, d);
        one.extend_paged(&case.q, &table, 0, len, scale);
        let mut a = vec![0.0f32; h * d];
        one.finalize_into(&mut a);

        let mut two = MhaSwiftKv::new_grouped(h, hkv, d);
        two.extend_paged(&case.q, &table, 0, cut, scale);
        two.extend_paged(&case.q, &table, cut, len, scale);
        let mut b = vec![0.0f32; h * d];
        two.finalize_into(&mut b);
        assert_eq!(a, b, "h={h} hkv={hkv} d={d} len={len} cut={cut}");
        table.release_into(&case.pool);
    });
}

#[test]
fn prop_recycled_blocks_decode_like_fresh_ones() {
    // Lane recycling scrambles which physical blocks a table holds and
    // leaves stale contents (f32 and Q15.17) in them. A table rebuilt
    // from recycled blocks must still match the contiguous reference on
    // raw bits in both numerics.
    prop::check("recycled pool blocks == fresh blocks", 25, |rng, _| {
        let case = PagedCase::random(rng, 1.0);
        let (h, hkv, d, len, bl) = (case.h, case.hkv, case.d, case.len, case.block_len);
        let scale = 1.0 / (d as f32).sqrt();

        // dirty the pool: check every block out, fill with garbage (f32
        // and mirror), release in a different order than allocated
        {
            let total = case.pool.total_blocks();
            let mut dirty = BlockTable::new(&case.pool, total * bl);
            dirty.ensure_tokens(&case.pool, total * bl);
            for t in 0..total * bl {
                for x in dirty.k_row_mut(t).iter_mut() {
                    *x = -7.5;
                }
                for x in dirty.v_row_mut(t).iter_mut() {
                    *x = 9.25;
                }
                dirty.quantize_row(t);
            }
            dirty.release_into(&case.pool);
        }
        // hold one block back so the rebuilt table gets a rotated set
        let held = case.pool.alloc();

        let mut table = case.build_table();
        let mut paged = MhaSwiftKv::new_grouped(h, hkv, d);
        paged.extend_paged(&case.q, &table, 0, len, scale);
        let mut got = vec![0.0f32; h * d];
        paged.finalize_into(&mut got);

        let mut contiguous = MhaSwiftKv::new_grouped(h, hkv, d);
        let mut want = vec![0.0f32; h * d];
        contiguous.attend(&case.q, &case.k, &case.v, len, scale, &mut want);
        assert_eq!(want, got, "h={h} hkv={hkv} d={d} len={len} bl={bl} (f32)");

        // Q15.17: the rebuilt mirror must fully overwrite stale garbage
        let lut = Exp2Lut::new();
        let fscale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let qq = vector::quantize(&case.q);
        let kq = vector::quantize(&case.k);
        let vq = vector::quantize(&case.v);
        let mut fpaged = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        fpaged.extend_paged(&lut, &qq, &table, 0, len, fscale);
        let mut fgot = vec![Fxp32::ZERO; h * d];
        fpaged.finalize_into(&mut fgot);
        let mut fcont = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut fwant = vec![Fxp32::ZERO; h * d];
        fcont.attend(&lut, &qq, &kq, &vq, len, fscale, &mut fwant);
        for (i, (x, y)) in fwant.iter().zip(&fgot).enumerate() {
            assert_eq!(x.raw(), y.raw(), "fxp flat-dim {i} diverged on recycled blocks");
        }

        table.release_into(&case.pool);
        case.pool.release(held);
    });
}

#[test]
fn paged_sweep_rejects_short_table() {
    // reading past the mapped blocks must fail loudly, not wrap
    let pool = BlockPool::new(2, 4, 8);
    let mut table = BlockTable::new(&pool, 8);
    table.ensure_tokens(&pool, 4); // one block only
    let mut mha = MhaSwiftKv::new_grouped(2, 2, 4);
    let q = vec![0.5f32; 8];
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        mha.extend_paged(&q, &table, 0, 6, 0.5);
    }));
    assert!(r.is_err(), "extend_paged beyond mapped blocks must panic");
    table.release_into(&pool);
}
