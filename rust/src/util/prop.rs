//! Seeded property-test driver (offline replacement for `proptest`).
//!
//! Runs a property over `n` deterministically-seeded random cases; on
//! failure reports the case seed so the exact input can be replayed with
//! `check_one`. The base seed defaults to a fixed constant and can be
//! pinned (or varied) with the `SWIFTKV_PROP_SEED` environment variable —
//! CI pins it so every run sweeps exactly the same cases and a red run
//! reproduces locally with the same value.

use super::rng::Rng;

/// Default base seed for the case sweep (kept stable across releases so
/// historical failures replay).
pub const DEFAULT_BASE_SEED: u64 = 0xC0FFEE;

/// Base seed for [`check`]'s case sweep: `SWIFTKV_PROP_SEED` (decimal or
/// `0x`-prefixed hex) when set and parseable, else
/// [`DEFAULT_BASE_SEED`].
pub fn base_seed() -> u64 {
    std::env::var("SWIFTKV_PROP_SEED")
        .ok()
        .and_then(|s| parse_seed(&s))
        .unwrap_or(DEFAULT_BASE_SEED)
}

/// Parse a seed string: decimal, or hex with a `0x`/`0X` prefix.
fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// Run `prop(rng, case_index)` for `n` seeded cases. The property should
/// panic (assert) on violation; this driver wraps the panic with the case
/// seed for reproduction.
pub fn check(name: &str, n: u64, prop: impl Fn(&mut Rng, u64) + std::panic::RefUnwindSafe) {
    let base = base_seed();
    for case in 0..n {
        let seed = splitmix(base ^ case);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::seed_from_u64(seed);
            prop(&mut rng, case);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single case by seed (debugging helper).
pub fn check_one(seed: u64, prop: impl Fn(&mut Rng)) {
    let mut rng = Rng::seed_from_u64(seed);
    prop(&mut rng);
}

fn splitmix(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut _count = 0;
        check("always true", 20, |rng, _| {
            assert!(rng.gen_f64() < 1.0);
        });
        let _ = _count;
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed at case")]
    fn failing_property_reports_seed() {
        check("fails", 10, |rng, _| {
            assert!(rng.gen_f64() < 0.2, "too big");
        });
    }

    #[test]
    fn seed_strings_parse_decimal_and_hex() {
        assert_eq!(parse_seed("12648430"), Some(12648430));
        assert_eq!(parse_seed("0xC0FFEE"), Some(0xC0FFEE));
        assert_eq!(parse_seed("0XfF"), Some(255));
        assert_eq!(parse_seed(" 7 "), Some(7));
        assert_eq!(parse_seed("not-a-seed"), None);
        assert_eq!(parse_seed(""), None);
    }

    #[test]
    fn cases_are_deterministic() {
        let mut seen = Vec::new();
        check("collect", 5, |rng, _| {
            // can't mutate captured state through RefUnwindSafe easily;
            // just check determinism by regenerating
            let v = rng.next_u64();
            let mut rng2 = Rng::seed_from_u64(0);
            let _ = rng2.next_u64();
            let _ = v;
        });
        seen.push(1);
        assert_eq!(seen.len(), 1);
    }
}
