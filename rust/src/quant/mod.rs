//! W4A8 quantization — the GEMV-mode number formats of Fig. 5.
//!
//! Activations are symmetric per-row INT8 (Q8.0); weights are symmetric
//! per-output-channel INT4 (Q4.0), stored packed two-per-byte the way the
//! KV-Weight Memory holds them. `INT4 × INT8 → INT32` accumulation is
//! exact, so the Rust GEMV here is bit-identical to the Pallas kernel
//! (`python/compile/kernels/gemv.py`) given the same quantized inputs —
//! an invariant the integration tests check through the PJRT runtime.

pub mod gemv;
pub mod int4;
pub mod int8;

pub use gemv::{
    gemm_w4a8_raw_cols_into, gemm_w4a8_raw_into, gemv_w4a8, gemv_w4a8_into, gemv_w4a8_raw_into,
    QuantLinear,
};
pub use int4::{pack_int4, quantize_int4, unpack_int4, Int4Matrix};
pub use int8::{quantize_int8, quantize_int8_into, QuantizedVec};
