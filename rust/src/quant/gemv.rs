//! W4A8 GEMV on the (modelled) SKV Processor Array.
//!
//! `INT8 activation × INT4 weight → INT32` accumulate, dequantized on
//! writeback — exact integer arithmetic, so results are bit-identical to
//! the Pallas GEMV kernel for identical quantized inputs.
//!
//! The inner MAC loops are dispatched through
//! [`crate::kernels::isa::active`] (AVX2 nibble-unpack + `madd` kernels
//! when available, the scalar four-accumulator loops otherwise); every
//! entry is exact integer arithmetic, so outputs are **bit-exact across
//! all dispatch targets**. The batched GEMM additionally blocks the
//! reduction dimension into [`GEMM_KC`]-lane panels, unpacking each
//! packed-nibble panel once and reusing it across every lane
//! (GotoBLAS-style cache blocking — see EXPERIMENTS.md §SIMD-dispatch).
//!
//! lint: hotpath

use super::int4::{unpack_int4, Int4Matrix};
use super::int8::QuantizedVec;

/// Reduction-dimension (K) panel length of the batched GEMM: the nibble
/// panel unpacked per column (`GEMM_KC` i8 lanes = 1 KiB) plus one
/// activation row segment per lane stay resident in L1 while every lane
/// MACs against them. Even, so panels start on a packed-byte boundary.
pub const GEMM_KC: usize = 1024;

/// `y = dequant(Wᵀ x)` for a packed INT4 matrix and an INT8 vector.
pub fn gemv_w4a8(x: &QuantizedVec, w: &Int4Matrix) -> Vec<f32> {
    // lint: allow(hotpath) — allocating convenience wrapper; the serving
    // path uses gemv_w4a8_into with caller-owned buffers.
    let mut out = vec![0.0f32; w.dout];
    gemv_w4a8_into(x, w, &mut out);
    out
}

/// [`gemv_w4a8`] into a caller-owned `[dout]` buffer (no allocation).
pub fn gemv_w4a8_into(x: &QuantizedVec, w: &Int4Matrix, out: &mut [f32]) {
    gemv_w4a8_raw_into(&x.data, x.scale, w, out);
}

/// The GEMV core on raw quantized lanes — `out = (Wᵀ xs) · xscale · wscale`.
///
/// Hot path (§Perf): the nibble unpack is fused into the MAC loop — each
/// packed byte contributes two lanes directly from registers, with four
/// i32 accumulators so the compiler vectorizes the reduction. This is the
/// software model of the 128-lane DSP column; see EXPERIMENTS.md §Perf
/// for the before/after. Taking `&[i8]` instead of [`QuantizedVec`] lets
/// the caller reuse one scratch buffer across layers
/// ([`QuantLinear::forward_into`]).
pub fn gemv_w4a8_raw_into(xs: &[i8], xscale: f32, w: &Int4Matrix, out: &mut [f32]) {
    assert_eq!(xs.len(), w.din, "dimension mismatch");
    assert_eq!(out.len(), w.dout, "output length mismatch");
    let t = crate::kernels::isa::active();
    let stride = w.din.div_ceil(2);
    for (j, o) in out.iter_mut().enumerate() {
        let col = &w.packed[j * stride..(j + 1) * stride];
        let acc = (t.w4a8_col)(col, w.din, xs);
        *o = acc as f32 * xscale * w.scales[j];
    }
}

/// Scalar body of one packed column's fused nibble-unpack + MAC loop —
/// the `w4a8_col` dispatch fallback and the bit-exactness reference for
/// the SIMD kernels. 2 bytes (4 lanes) per step with four independent
/// i32 accumulators so the compiler vectorizes the reduction.
pub(crate) fn w4a8_col_scalar(col: &[u8], din: usize, xs: &[i8]) -> i32 {
    debug_assert_eq!(xs.len(), din);
    debug_assert!(col.len() >= din.div_ceil(2));
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    let mut acc2 = 0i32;
    let mut acc3 = 0i32;
    let pairs = din / 2;
    let mut b = 0;
    // 2 bytes (4 lanes) per step
    while b + 2 <= pairs {
        let byte0 = col[b];
        let byte1 = col[b + 1];
        let lo0 = (((byte0 & 0x0F) << 4) as i8 >> 4) as i32;
        let hi0 = ((byte0 >> 4) as i8).wrapping_shl(4).wrapping_shr(4) as i32;
        let lo1 = (((byte1 & 0x0F) << 4) as i8 >> 4) as i32;
        let hi1 = ((byte1 >> 4) as i8).wrapping_shl(4).wrapping_shr(4) as i32;
        acc0 += xs[2 * b] as i32 * lo0;
        acc1 += xs[2 * b + 1] as i32 * hi0;
        acc2 += xs[2 * b + 2] as i32 * lo1;
        acc3 += xs[2 * b + 3] as i32 * hi1;
        b += 2;
    }
    while b < pairs {
        let byte = col[b];
        let lo = (((byte & 0x0F) << 4) as i8 >> 4) as i32;
        let hi = ((byte >> 4) as i8).wrapping_shl(4).wrapping_shr(4) as i32;
        acc0 += xs[2 * b] as i32 * lo;
        acc1 += xs[2 * b + 1] as i32 * hi;
        b += 1;
    }
    if din % 2 == 1 {
        let byte = col[pairs];
        let lo = (((byte & 0x0F) << 4) as i8 >> 4) as i32;
        acc0 += xs[din - 1] as i32 * lo;
    }
    acc0 + acc1 + acc2 + acc3
}

/// Scalar i8·i8 → i32 dot — the `dot_i8` dispatch fallback (the batched
/// GEMM's panel MAC) and the bit-exactness reference for the SIMD
/// kernels. Four independent accumulators, exact integer arithmetic.
pub(crate) fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
    for i in 0..chunks {
        let k = 4 * i;
        a0 += a[k] as i32 * b[k] as i32;
        a1 += a[k + 1] as i32 * b[k + 1] as i32;
        a2 += a[k + 2] as i32 * b[k + 2] as i32;
        a3 += a[k + 3] as i32 * b[k + 3] as i32;
    }
    let mut acc = a0 + a1 + a2 + a3;
    for i in 4 * chunks..n {
        acc += a[i] as i32 * b[i] as i32;
    }
    acc
}

/// The batched GEMM core on raw quantized lanes: `b` INT8 activation
/// rows share **one** pass over the packed INT4 weight matrix —
/// `out[i] = (Wᵀ xs[i]) · xscales[i] · wscale` for every lane at once.
///
/// Hot path (§Perf): decoding is weight-bandwidth bound, and `b`
/// independent [`gemv_w4a8_raw_into`] calls stream (and nibble-unpack)
/// the packed matrix `b` times per batch step. Here every packed column
/// byte is unpacked once and MAC'd against all lanes' activation rows
/// from registers (lane blocks of 4, one i32 accumulator pair per
/// lane), so weight bytes moved — and unpack work done — per batch step
/// are constant in `b`. The i32 accumulation is exact and the writeback
/// uses the same expression as the GEMV, so every lane's output is
/// **bit-identical** to a solo [`gemv_w4a8_raw_into`] over the same
/// quantized inputs (unit tests below; `tests/prop_batched_decode.rs`
/// asserts it end-to-end through the model).
///
/// `xs` is row-major `[b, din]`, `out` row-major `[b, dout]`, with
/// `b = xscales.len()`.
pub fn gemm_w4a8_raw_into(xs: &[i8], xscales: &[f32], w: &Int4Matrix, out: &mut [f32]) {
    gemm_w4a8_raw_cols_into(xs, xscales, w, 0, w.dout, out);
}

/// [`gemm_w4a8_raw_into`] restricted to output columns `j0..j1` — the
/// operator-splitting unit of the serving path's worker pool: disjoint
/// column ranges of one batched GEMM run on different workers, each
/// writing only its own columns of every lane's output row.
pub fn gemm_w4a8_raw_cols_into(
    xs: &[i8],
    xscales: &[f32],
    w: &Int4Matrix,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    // SAFETY: `out` is a valid exclusive borrow of the whole buffer.
    unsafe { gemm_w4a8_raw_cols_ptr(xs, xscales, w, j0, j1, out.as_mut_ptr(), out.len()) }
}

/// Raw-pointer form of [`gemm_w4a8_raw_cols_into`], for callers that
/// split one output buffer across worker threads by column range.
///
/// GotoBLAS-style K blocking: per column, the packed nibbles are
/// unpacked once per [`GEMM_KC`]-lane panel into a stack-resident i8
/// panel, and every lane MACs its activation-row segment against that
/// panel through the dispatched `dot_i8` microkernel. The i32 partial
/// sums are exact (integer adds reassociate freely), so lane outputs
/// stay **bit-identical** to a solo [`gemv_w4a8_raw_into`]; partials for
/// multi-panel `din` ride in the output slot bit-cast (i32 in the f32
/// bits) so the hot path stays allocation-free.
///
/// # Safety
/// `out` must point to a live `[b * w.dout]` f32 buffer (`b =
/// xscales.len()`, `out_len` its exact length) for the duration of the
/// call, and concurrent callers over the same buffer must use disjoint
/// `j0..j1` ranges — each call writes only the elements
/// `out[i * w.dout + j]` for `j0 <= j < j1`, nothing else.
pub unsafe fn gemm_w4a8_raw_cols_ptr(
    xs: &[i8],
    xscales: &[f32],
    w: &Int4Matrix,
    j0: usize,
    j1: usize,
    out: *mut f32,
    out_len: usize,
) {
    let b = xscales.len();
    assert_eq!(xs.len(), b * w.din, "activation batch dimension mismatch");
    assert_eq!(out_len, b * w.dout, "output batch length mismatch");
    assert!(j0 <= j1 && j1 <= w.dout, "column range out of bounds");
    let t = crate::kernels::isa::active();
    let stride = w.din.div_ceil(2);
    let mut panel = [0i8; GEMM_KC];
    for j in j0..j1 {
        let col = &w.packed[j * stride..(j + 1) * stride];
        let wscale = w.scales[j];
        if w.din == 0 {
            for i in 0..b {
                // SAFETY: i*w.dout + j < b*w.dout = out_len (asserted
                // above), and j is inside this call's exclusive j0..j1.
                unsafe { out.add(i * w.dout + j).write(0.0) };
            }
            continue;
        }
        let mut k0 = 0usize;
        while k0 < w.din {
            let k1 = (k0 + GEMM_KC).min(w.din);
            let klen = k1 - k0;
            // GEMM_KC is even, so each panel starts on a byte boundary
            unpack_int4(&col[k0 / 2..], &mut panel[..klen]);
            let first = k0 == 0;
            let last = k1 == w.din;
            for i in 0..b {
                let row = &xs[i * w.din + k0..i * w.din + k1];
                let part = (t.dot_i8)(&panel[..klen], row);
                let idx = i * w.dout + j;
                // i32 partials live in the f32 slot's bits between
                // panels; the last panel dequantizes on writeback
                let acc = if first {
                    part
                } else {
                    // SAFETY: idx < out_len (asserted above) and j is in
                    // our exclusive j0..j1 range; a previous panel of
                    // this same call stored the i32 partial there.
                    unsafe { (out.add(idx) as *mut u32).read() as i32 + part }
                };
                if last {
                    // SAFETY: idx < out_len, j within our exclusive
                    // column range — nobody else writes this slot.
                    unsafe { out.add(idx).write(acc as f32 * xscales[i] * wscale) };
                } else {
                    // SAFETY: as above; parks the i32 partial in the f32
                    // slot's bits until the final panel dequantizes it.
                    unsafe { (out.add(idx) as *mut u32).write(acc as u32) };
                }
            }
            k0 = k1;
        }
    }
}

/// A quantized linear layer: packed weights + the f32 forward that first
/// quantizes its activation (the full SFU→Array round trip of Fig. 5(c)).
#[derive(Debug, Clone)]
pub struct QuantLinear {
    pub weight: Int4Matrix,
}

impl QuantLinear {
    pub fn new(weight: Int4Matrix) -> Self {
        QuantLinear { weight }
    }

    /// Quantize `x` to INT8 and run the W4A8 GEMV.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        // lint: allow(hotpath) — allocating convenience wrapper; the
        // serving path uses forward_into with caller-owned scratch.
        let mut out = vec![0.0f32; self.weight.dout];
        let mut qbuf = vec![0i8; self.weight.din];
        self.forward_into(x, &mut qbuf, &mut out);
        out
    }

    /// [`Self::forward`] through caller-owned scratch: `qbuf` (≥ `din`
    /// lanes, only the first `din` are used) holds the INT8 activation,
    /// `out` (`dout` lanes) receives the result. No allocation.
    pub fn forward_into(&self, x: &[f32], qbuf: &mut [i8], out: &mut [f32]) {
        let qb = &mut qbuf[..self.weight.din];
        let scale = super::int8::quantize_int8_into(x, qb);
        gemv_w4a8_raw_into(qb, scale, &self.weight, out);
    }

    pub fn din(&self) -> usize {
        self.weight.din
    }

    pub fn dout(&self) -> usize {
        self.weight.dout
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::int8::quantize_int8;
    use crate::util::Rng;

    fn random_mat(seed: u64, din: usize, dout: usize) -> (Vec<f32>, Int4Matrix) {
        let mut rng = Rng::seed_from_u64(seed);
        let w = rng.uniform_vec(din * dout, 0.5);
        let m = Int4Matrix::quantize(&w, din, dout);
        (w, m)
    }

    #[test]
    fn matches_exact_integer_reference() {
        let mut rng = Rng::seed_from_u64(1);
        let (din, dout) = (64, 32);
        let (_, m) = random_mat(2, din, dout);
        let x = rng.uniform_vec(din, 1.0);
        let xq = quantize_int8(&x);

        let got = gemv_w4a8(&xq, &m);
        // independent reference through the dequantized matrix
        let wd = m.dequantize();
        let xd = xq.dequantize();
        for j in 0..dout {
            let want: f32 = (0..din).map(|i| xd[i] * wd[i * dout + j]).sum();
            assert!(
                (got[j] - want).abs() < 1e-3 * (1.0 + want.abs()),
                "col {j}: {} vs {want}",
                got[j]
            );
        }
    }

    #[test]
    fn quantized_gemv_close_to_f32() {
        let mut rng = Rng::seed_from_u64(3);
        let (din, dout) = (256, 128);
        let (w, m) = random_mat(4, din, dout);
        let x = rng.uniform_vec(din, 1.0);
        let got = QuantLinear::new(m).forward(&x);
        let mut max_ref = 0.0f32;
        let mut max_err = 0.0f32;
        for j in 0..dout {
            let want: f32 = (0..din).map(|i| x[i] * w[i * dout + j]).sum();
            max_ref = max_ref.max(want.abs());
            max_err = max_err.max((got[j] - want).abs());
        }
        assert!(
            max_err / max_ref < 0.25,
            "relative error {max_err}/{max_ref}"
        );
    }

    #[test]
    fn deterministic() {
        let (_, m) = random_mat(9, 32, 16);
        let x = vec![0.123f32; 32];
        let l = QuantLinear::new(m);
        assert_eq!(l.forward(&x), l.forward(&x));
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn dimension_mismatch_panics() {
        let (_, m) = random_mat(5, 16, 8);
        let xq = quantize_int8(&[1.0; 8]);
        gemv_w4a8(&xq, &m);
    }

    /// Build `b` quantized activation rows for a `din`-wide matrix.
    fn random_batch(seed: u64, b: usize, din: usize) -> (Vec<i8>, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut qs = vec![0i8; b * din];
        let mut scales = vec![0.0f32; b];
        for i in 0..b {
            let x = rng.uniform_vec(din, 1.0 + i as f32 * 0.25);
            scales[i] = crate::quant::int8::quantize_int8_into(&x, &mut qs[i * din..(i + 1) * din]);
        }
        (qs, scales)
    }

    #[test]
    fn gemm_bit_identical_to_per_lane_gemv() {
        // the whole point of the batched kernel: one shared weight pass
        // must reproduce every lane's GEMV output bit for bit — across
        // batch widths (incl. the 4-lane block boundary and remainders)
        // and an odd `din` (exercises the tail nibble)
        for (din, dout) in [(64usize, 32usize), (33, 17), (256, 96)] {
            let (_, m) = random_mat(11, din, dout);
            for b in [1usize, 2, 3, 4, 5, 8] {
                let (qs, scales) = random_batch(100 + b as u64, b, din);
                let mut batched = vec![0.0f32; b * dout];
                gemm_w4a8_raw_into(&qs, &scales, &m, &mut batched);
                let mut solo = vec![0.0f32; dout];
                for i in 0..b {
                    gemv_w4a8_raw_into(&qs[i * din..(i + 1) * din], scales[i], &m, &mut solo);
                    assert_eq!(
                        &batched[i * dout..(i + 1) * dout],
                        &solo[..],
                        "{din}x{dout} b={b}: lane {i} diverged from its GEMV"
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_column_ranges_compose_to_the_full_pass() {
        // the worker-pool split: disjoint column ranges must tile the
        // same output the single full-range call produces
        let (din, dout) = (48usize, 40usize);
        let (_, m) = random_mat(21, din, dout);
        let b = 5;
        let (qs, scales) = random_batch(77, b, din);
        let mut full = vec![0.0f32; b * dout];
        gemm_w4a8_raw_into(&qs, &scales, &m, &mut full);
        let mut tiled = vec![0.0f32; b * dout];
        for (j0, j1) in [(0usize, 7usize), (7, 13), (13, 40)] {
            gemm_w4a8_raw_cols_into(&qs, &scales, &m, j0, j1, &mut tiled);
        }
        assert_eq!(full, tiled);
        // an empty range writes nothing
        gemm_w4a8_raw_cols_into(&qs, &scales, &m, 9, 9, &mut tiled);
        assert_eq!(full, tiled);
    }

    #[test]
    #[should_panic(expected = "column range out of bounds")]
    fn gemm_rejects_out_of_range_columns() {
        let (_, m) = random_mat(5, 16, 8);
        let (qs, scales) = random_batch(5, 2, 16);
        let mut out = vec![0.0f32; 2 * 8];
        gemm_w4a8_raw_cols_into(&qs, &scales, &m, 4, 9, &mut out);
    }

    #[test]
    #[should_panic(expected = "activation batch dimension mismatch")]
    fn gemm_rejects_wrong_batch_shape() {
        let (_, m) = random_mat(5, 16, 8);
        let (qs, scales) = random_batch(5, 2, 16);
        let mut out = vec![0.0f32; 3 * 8];
        // 3 scales over 2 rows of activations
        let three = [scales[0], scales[1], 1.0];
        gemm_w4a8_raw_into(&qs, &three, &m, &mut out);
    }
}
