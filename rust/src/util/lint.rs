//! Repo-invariant lint pass — the static-analysis gate behind
//! `cargo run --bin lint` and `tests/lint_repo.rs`.
//!
//! Four rules, each encoding an invariant the compiler cannot check:
//!
//! 1. **unsafe-safety** — every `unsafe` keyword (block, fn, impl) must
//!    carry a `// SAFETY:` comment on the same or an immediately
//!    preceding comment/attribute line, or a `# Safety` doc section.
//!    Function-pointer *types* (`unsafe fn(..)`) are exempt.
//! 2. **hotpath** — files whose module docs carry the `lint: hotpath`
//!    marker as a standalone `//!` line must not allocate or read
//!    clocks on the decode path: `.unwrap()` / `.expect(` /
//!    `Instant::now` / `vec![` / `.collect()` / `format!(` / … are
//!    denied outside `#[cfg(test)]` regions unless a
//!    `lint: allow(hotpath)` waiver covers the lines.
//! 3. **kernel-parity** — every `KernelTable` initializer (scalar,
//!    AVX2, NEON) must spell out exactly the fields of the struct
//!    definition; `..` defaulting is rejected so a new kernel entry
//!    cannot silently fall back to scalar on one ISA.
//! 4. **bench-gate** — every substring in `bench_gate`'s default gate
//!    list must match at least one benchmark name in
//!    `BENCH_baseline.json` (or, while the baseline is a placeholder,
//!    one string literal in `benches/`), so the perf gate cannot rot
//!    into matching nothing.
//!
//! The scanner is deliberately lexical: [`mask`] blanks comments,
//! strings, and char literals while preserving line structure, and the
//! rules run over the masked text (except where the *content* of a
//! comment or literal is the subject). No rustc internals, no proc
//! macros — the pass must run on stable with zero dependencies.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// One rule violation at a specific source location.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to the crate root (forward slashes).
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// Rule slug from [`RULES`].
    pub rule: &'static str,
    /// Human-readable description of what to fix.
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Rule catalog: `(slug, one-line description)`. `lint-infra` covers
/// failures of the lint pass itself (missing inputs it must scan).
pub const RULES: &[(&str, &str)] = &[
    (
        "unsafe-safety",
        "every `unsafe` block, fn, or impl carries a `// SAFETY:` comment or `# Safety` doc",
    ),
    (
        "hotpath",
        "marker-annotated hot-path files never allocate, format, or read clocks outside tests",
    ),
    (
        "kernel-parity",
        "every KernelTable initializer spells out the exact field set of the struct (no `..`)",
    ),
    (
        "bench-gate",
        "each bench_gate default substring matches a baseline benchmark name (or benches literal)",
    ),
    (
        "lint-infra",
        "inputs the lint pass must scan (isa tables, gate default, baseline) exist and parse",
    ),
];

/// Marker text that, written as a whole `//! <marker>` line, opts a
/// file into the hot-path rule. Matched with exact `trim()` equality,
/// so prose *mentioning* the marker never opts a file in.
const HOTPATH_MARK: &str = "lint: hotpath";

/// Waiver needle: any line containing it exempts itself and the
/// following contiguous run of non-blank lines from the hot-path rule.
const HOTPATH_WAIVER: &str = "lint: allow(hotpath)";

/// Tokens denied in hot-path files — heap allocation, lazy formatting,
/// panicking extractors, and wall-clock reads.
const HOTPATH_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "Instant::now",
    "SystemTime::now",
    "vec![",
    ".collect()",
    "format!(",
    ".to_string()",
    ".to_vec()",
    "Box::new(",
    "String::from(",
    "Vec::with_capacity(",
];

// ---------------------------------------------------------------------------
// Lexical helpers
// ---------------------------------------------------------------------------

fn is_word_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Blank out comments, string literals, and char literals while
/// preserving the line structure (every newline survives; everything
/// blanked becomes spaces). Rules that care about *code* tokens scan
/// the masked text so commented-out or quoted code never matches;
/// rules that care about comment *content* read the raw lines.
pub fn mask(source: &str) -> String {
    let chars: Vec<char> = source.chars().collect();
    let mut out = String::with_capacity(source.len());
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        // Line comment (covers `//`, `///`, `//!`).
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < chars.len() && chars[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment, nested.
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(if chars[i] == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            continue;
        }
        // Raw (and raw-byte) string: r"..", r#".."#, br".." — only when
        // the `r`/`b` is not the tail of an identifier.
        let raw_prefix = if c == 'b' && chars.get(i + 1) == Some(&'r') {
            2
        } else if c == 'r' {
            1
        } else {
            0
        };
        if raw_prefix > 0 && (i == 0 || !is_word_char(chars[i - 1])) {
            let mut j = i + raw_prefix;
            let mut hashes = 0usize;
            while chars.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if chars.get(j) == Some(&'"') {
                // Blank prefix, hashes, and opening quote.
                while i <= j {
                    out.push(' ');
                    i += 1;
                }
                // Blank content until `"` followed by `hashes` hashes.
                'content: while i < chars.len() {
                    if chars[i] == '"' {
                        let mut k = 1usize;
                        while k <= hashes && chars.get(i + k) == Some(&'#') {
                            k += 1;
                        }
                        if k == hashes + 1 {
                            for _ in 0..=hashes {
                                out.push(' ');
                                i += 1;
                            }
                            break 'content;
                        }
                    }
                    out.push(if chars[i] == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
                continue;
            }
        }
        // Normal (and byte) string literal with escapes. An escaped
        // newline (the `\` line-continuation) must keep its newline, or
        // every later line number would shift.
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < chars.len() && chars[i] != '"' {
                if chars[i] == '\\' && i + 1 < chars.len() {
                    out.push(' ');
                    out.push(if chars[i + 1] == '\n' { '\n' } else { ' ' });
                    i += 2;
                } else {
                    out.push(if chars[i] == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            if i < chars.len() {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime: 'x' or '\n' is a literal; 'a (no
        // closing quote two chars on) is a lifetime and passes through.
        if c == '\'' {
            let is_char = chars.get(i + 1) == Some(&'\\') || chars.get(i + 2) == Some(&'\'');
            if is_char {
                out.push(' ');
                i += 1;
                while i < chars.len() && chars[i] != '\'' {
                    if chars[i] == '\\' && i + 1 < chars.len() {
                        out.push_str("  ");
                        i += 2;
                    } else {
                        out.push(' ');
                        i += 1;
                    }
                }
                if i < chars.len() {
                    out.push(' ');
                    i += 1;
                }
                continue;
            }
        }
        out.push(c);
        i += 1;
    }
    out
}

/// Byte offsets of every whole-word occurrence of `word` in `code`.
/// Word boundaries are `[A-Za-z0-9_]`; offsets index the masked text,
/// never the raw source.
pub fn word_occurrences(code: &str, word: &str) -> Vec<usize> {
    let b = code.as_bytes();
    let w = word.as_bytes();
    let mut out = Vec::new();
    if w.is_empty() {
        return out;
    }
    let mut i = 0usize;
    while i + w.len() <= b.len() {
        if &b[i..i + w.len()] == w {
            let before_ok = i == 0 || !is_word_byte(b[i - 1]);
            let after_ok = i + w.len() == b.len() || !is_word_byte(b[i + w.len()]);
            if before_ok && after_ok {
                out.push(i);
                i += w.len();
                continue;
            }
        }
        i += 1;
    }
    out
}

/// 1-based line number of byte offset `at` (mask preserves newlines,
/// so masked offsets map to the same line as the raw source).
pub fn line_of(code: &str, at: usize) -> usize {
    code.as_bytes()[..at].iter().filter(|&&b| b == b'\n').count() + 1
}

/// Body between the brace at `open` and its matching close (exclusive).
fn brace_body(code: &str, open: usize) -> Option<&str> {
    let bytes = code.as_bytes();
    if bytes.get(open) != Some(&b'{') {
        return None;
    }
    let mut depth = 0usize;
    let mut i = open;
    while i < bytes.len() {
        match bytes[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&code[open + 1..i]);
                }
            }
            _ => {}
        }
        i += 1;
    }
    None
}

/// Split on commas at bracket depth 0 (tracking `()[]{}`).
fn split_top_level(body: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut start = 0usize;
    for (i, &b) in body.as_bytes().iter().enumerate() {
        match b {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b',' if depth == 0 => {
                out.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&body[start..]);
    out
}

/// Contents of every normal and raw string literal in `source`,
/// skipping comments and char literals.
pub fn string_literals(source: &str) -> Vec<String> {
    let chars: Vec<char> = source.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < chars.len() && chars[i] != '\n' {
                i += 1;
            }
            continue;
        }
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            i += 2;
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        let raw_prefix = if c == 'b' && chars.get(i + 1) == Some(&'r') {
            2
        } else if c == 'r' {
            1
        } else {
            0
        };
        if raw_prefix > 0 && (i == 0 || !is_word_char(chars[i - 1])) {
            let mut j = i + raw_prefix;
            let mut hashes = 0usize;
            while chars.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if chars.get(j) == Some(&'"') {
                i = j + 1;
                let mut s = String::new();
                while i < chars.len() {
                    if chars[i] == '"' {
                        let mut k = 1usize;
                        while k <= hashes && chars.get(i + k) == Some(&'#') {
                            k += 1;
                        }
                        if k == hashes + 1 {
                            i += hashes + 1;
                            break;
                        }
                    }
                    s.push(chars[i]);
                    i += 1;
                }
                out.push(s);
                continue;
            }
        }
        if c == '"' {
            i += 1;
            let mut s = String::new();
            while i < chars.len() && chars[i] != '"' {
                if chars[i] == '\\' && i + 1 < chars.len() {
                    s.push(chars[i]);
                    s.push(chars[i + 1]);
                    i += 2;
                } else {
                    s.push(chars[i]);
                    i += 1;
                }
            }
            i += 1;
            out.push(s);
            continue;
        }
        if c == '\'' {
            let is_char = chars.get(i + 1) == Some(&'\\') || chars.get(i + 2) == Some(&'\'');
            if is_char {
                i += 1;
                while i < chars.len() && chars[i] != '\'' {
                    if chars[i] == '\\' && i + 1 < chars.len() {
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                i += 1;
                continue;
            }
        }
        i += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 1: unsafe-safety
// ---------------------------------------------------------------------------

/// True when the `unsafe` on 1-based `line` is justified: the raw line
/// itself mentions `SAFETY:`, or the contiguous run of comment /
/// attribute lines immediately above contains `SAFETY:` or `# Safety`.
fn has_safety_justification(raw_lines: &[&str], line: usize) -> bool {
    let Some(idx) = line.checked_sub(1) else {
        return false;
    };
    if idx >= raw_lines.len() {
        return false;
    }
    if raw_lines[idx].contains("SAFETY:") {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = raw_lines[i].trim_start();
        if !(t.starts_with("//") || t.starts_with("#[") || t.starts_with("#!")) {
            return false;
        }
        if t.contains("SAFETY:") || t.contains("# Safety") {
            return true;
        }
    }
    false
}

/// Rule 1: every `unsafe` keyword needs a safety justification.
pub fn check_unsafe_safety(file: &str, source: &str) -> Vec<Violation> {
    let code = mask(source);
    let raw_lines: Vec<&str> = source.lines().collect();
    let mut out = Vec::new();
    for at in word_occurrences(&code, "unsafe") {
        let rest = code[at + "unsafe".len()..].trim_start();
        if let Some(after_fn) = rest.strip_prefix("fn") {
            // `unsafe fn(` with no name is a function-pointer *type*
            // (e.g. a vtable field), not a declaration — nothing to doc.
            if after_fn.trim_start().starts_with('(') {
                continue;
            }
        }
        let line = line_of(&code, at);
        if !has_safety_justification(&raw_lines, line) {
            out.push(Violation {
                file: file.to_string(),
                line,
                rule: "unsafe-safety",
                message: "`unsafe` without a `// SAFETY:` comment (same line or immediately \
                          above) or `# Safety` doc section"
                    .to_string(),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 2: hotpath
// ---------------------------------------------------------------------------

fn is_hotpath_annotated(source: &str) -> bool {
    // Built by formatting rather than spelled inline so no line of
    // *this* file ever trims to the exact marker.
    let marker = format!("//! {HOTPATH_MARK}");
    source.lines().any(|l| l.trim() == marker)
}

/// Rule 2: marker-annotated files must keep the decode path free of
/// allocation, formatting, panicking extractors, and clock reads.
pub fn check_hotpath(file: &str, source: &str) -> Vec<Violation> {
    if !is_hotpath_annotated(source) {
        return Vec::new();
    }
    let code = mask(source);
    let raw_lines: Vec<&str> = source.lines().collect();
    let masked_lines: Vec<&str> = code.lines().collect();
    let n = raw_lines.len();

    // Waivers: the needle line plus the following contiguous run of
    // non-blank lines (covers a struct-init or call it annotates).
    let mut waived = vec![false; n];
    let mut i = 0usize;
    while i < n {
        if raw_lines[i].contains(HOTPATH_WAIVER) {
            let mut j = i;
            while j < n && !raw_lines[j].trim().is_empty() {
                waived[j] = true;
                j += 1;
            }
            i = j;
        } else {
            i += 1;
        }
    }

    // `#[cfg(test)]`-style attribute followed (within 3 lines) by a
    // `mod` line marks the start of the test region; everything from
    // there to EOF is exempt.
    let mut test_start = n;
    for (i, l) in masked_lines.iter().enumerate() {
        if l.contains("#[cfg(") && l.contains("test") && !l.contains("not(test)") {
            let end = (i + 4).min(masked_lines.len());
            if masked_lines[i + 1..end].iter().any(|m| m.trim_start().starts_with("mod ")) {
                test_start = test_start.min(i);
            }
        }
    }

    let mut out = Vec::new();
    for (i, l) in masked_lines.iter().enumerate() {
        if i >= test_start || waived.get(i).copied().unwrap_or(false) {
            continue;
        }
        for tok in HOTPATH_TOKENS {
            if l.contains(tok) {
                out.push(Violation {
                    file: file.to_string(),
                    line: i + 1,
                    rule: "hotpath",
                    message: format!(
                        "hot-path file uses `{tok}` outside a test region; move it off the \
                         decode path or add a `{HOTPATH_WAIVER}` waiver"
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 3: kernel-parity
// ---------------------------------------------------------------------------

/// One `KernelTable { .. }` struct-literal found in a source file.
#[derive(Debug)]
pub struct KernelInit {
    /// 1-based line of the `KernelTable` token.
    pub line: usize,
    /// Whether the literal used `..` base-struct defaulting.
    pub has_rest: bool,
    /// Field names spelled out in the literal.
    pub fields: BTreeSet<String>,
}

/// Field names of the `struct KernelTable { .. }` definition.
pub fn kernel_struct_fields(source: &str) -> Option<BTreeSet<String>> {
    let code = mask(source);
    for at in word_occurrences(&code, "struct") {
        let rest = code[at + "struct".len()..].trim_start();
        let Some(after_name) = rest.strip_prefix("KernelTable") else {
            continue;
        };
        if !after_name.trim_start().starts_with('{') {
            continue;
        }
        let open = at + code[at..].find('{')?;
        let body = brace_body(&code, open)?;
        let mut fields = BTreeSet::new();
        for entry in split_top_level(body) {
            let e = entry.trim().trim_start_matches("pub ").trim_start();
            let name: String = e.chars().take_while(|&c| is_word_char(c)).collect();
            if !name.is_empty() {
                fields.insert(name);
            }
        }
        return Some(fields);
    }
    None
}

/// Every `KernelTable` struct-literal initializer in `source`: the
/// token must be preceded (ignoring whitespace) by `=` and followed by
/// `{`, which excludes type ascriptions, `use` paths, references, and
/// return types.
pub fn kernel_init_fields(source: &str) -> Vec<KernelInit> {
    let code = mask(source);
    let mut out = Vec::new();
    for at in word_occurrences(&code, "KernelTable") {
        if !code[..at].trim_end().ends_with('=') {
            continue;
        }
        let after = &code[at + "KernelTable".len()..];
        if !after.trim_start().starts_with('{') {
            continue;
        }
        let Some(rel) = after.find('{') else {
            continue;
        };
        let open = at + "KernelTable".len() + rel;
        let Some(body) = brace_body(&code, open) else {
            continue;
        };
        let mut fields = BTreeSet::new();
        let mut has_rest = false;
        for entry in split_top_level(body) {
            let e = entry.trim();
            if e.is_empty() {
                continue;
            }
            if e.starts_with("..") {
                has_rest = true;
                continue;
            }
            let name: String = e.chars().take_while(|&c| is_word_char(c)).collect();
            if !name.is_empty() {
                fields.insert(name);
            }
        }
        out.push(KernelInit { line: line_of(&code, at), has_rest, fields });
    }
    out
}

/// Rule 3: each ISA file's `KernelTable` initializer must spell out
/// exactly the struct's fields. `struct_file` holds the definition;
/// every entry of `table_files` must contain at least one initializer.
pub fn check_kernel_parity(
    struct_file: (&str, &str),
    table_files: &[(&str, &str)],
) -> Vec<Violation> {
    let mut out = Vec::new();
    let Some(want) = kernel_struct_fields(struct_file.1) else {
        out.push(Violation {
            file: struct_file.0.to_string(),
            line: 1,
            rule: "kernel-parity",
            message: "no `struct KernelTable` definition found".to_string(),
        });
        return out;
    };
    for (file, src) in table_files {
        let inits = kernel_init_fields(src);
        if inits.is_empty() {
            out.push(Violation {
                file: (*file).to_string(),
                line: 1,
                rule: "kernel-parity",
                message: "no `KernelTable` initializer found; every ISA file must build a \
                          full dispatch table"
                    .to_string(),
            });
            continue;
        }
        for init in inits {
            if init.has_rest {
                out.push(Violation {
                    file: (*file).to_string(),
                    line: init.line,
                    rule: "kernel-parity",
                    message: "initializer uses `..` defaulting; spell out every entry so a \
                              new kernel cannot silently fall back on one ISA"
                        .to_string(),
                });
            } else {
                let missing: Vec<&str> =
                    want.difference(&init.fields).map(String::as_str).collect();
                if !missing.is_empty() {
                    out.push(Violation {
                        file: (*file).to_string(),
                        line: init.line,
                        rule: "kernel-parity",
                        message: format!("initializer missing entries: {}", missing.join(", ")),
                    });
                }
            }
            let extra: Vec<&str> = init.fields.difference(&want).map(String::as_str).collect();
            if !extra.is_empty() {
                out.push(Violation {
                    file: (*file).to_string(),
                    line: init.line,
                    rule: "kernel-parity",
                    message: format!(
                        "initializer has entries not in the struct: {}",
                        extra.join(", ")
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 4: bench-gate
// ---------------------------------------------------------------------------

/// Extract the default gate list from `bench_gate.rs`: the second
/// (string) argument of the `get_or("gate", "...")` call.
pub fn parse_gate_default(source: &str) -> Option<String> {
    let at = source.find("get_or(\"gate\"")?;
    let rest = &source[at..];
    let comma = rest.find(',')?;
    let rest = &rest[comma + 1..];
    let q1 = rest.find('"')?;
    let rest = &rest[q1 + 1..];
    let q2 = rest.find('"')?;
    Some(rest[..q2].to_string())
}

/// Benchmark names in a `BENCH_baseline.json` document: the value of
/// every `"name"` key. A placeholder baseline (no benchmarks) yields
/// an empty vec, which switches [`check_bench_gate`] to its fallback.
pub fn json_bench_names(doc: &str) -> Vec<String> {
    let b = doc.as_bytes();
    let key = b"\"name\"";
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + key.len() <= b.len() {
        if &b[i..i + key.len()] != key {
            i += 1;
            continue;
        }
        let mut j = i + key.len();
        while j < b.len() && b[j].is_ascii_whitespace() {
            j += 1;
        }
        if b.get(j) != Some(&b':') {
            i += 1;
            continue;
        }
        j += 1;
        while j < b.len() && b[j].is_ascii_whitespace() {
            j += 1;
        }
        if b.get(j) != Some(&b'"') {
            i += 1;
            continue;
        }
        j += 1;
        let start = j;
        while j < b.len() && b[j] != b'"' {
            if b[j] == b'\\' {
                j += 1;
            }
            j += 1;
        }
        out.push(String::from_utf8_lossy(&b[start..j.min(b.len())]).into_owned());
        i = j + 1;
    }
    out
}

/// Rule 4: every comma-separated substring of the gate default must
/// match at least one baseline benchmark name — or, when the baseline
/// is still a placeholder with no names, one string literal from the
/// `benches/` sources (where the runtime names are assembled).
pub fn check_bench_gate(
    file: &str,
    gate: &str,
    names: &[String],
    fallback: &[String],
) -> Vec<Violation> {
    let mut out = Vec::new();
    for part in gate.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        let covered = if names.is_empty() {
            fallback.iter().any(|l| l.contains(p))
        } else {
            names.iter().any(|n| n.contains(p))
        };
        if !covered {
            let scope = if names.is_empty() {
                "no benches/ string literal (placeholder baseline)"
            } else {
                "no baseline benchmark name"
            };
            out.push(Violation {
                file: file.to_string(),
                line: 1,
                rule: "bench-gate",
                message: format!("gate substring `{p}` matches {scope} — the perf gate \
                                  would silently cover nothing"),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Crate driver
// ---------------------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let Ok(entries) = fs::read_dir(dir) else {
        return Ok(()); // missing dir (e.g. no benches/) is not an error
    };
    for entry in entries {
        let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn infra(file: &str, message: String) -> Violation {
    Violation { file: file.to_string(), line: 1, rule: "lint-infra", message }
}

/// Run every rule over the crate rooted at `rust_root` (the directory
/// holding `Cargo.toml`; `BENCH_baseline.json` is expected one level
/// up, at the repo root). Returns all violations, sorted by file/line.
pub fn lint_crate(rust_root: &Path) -> Result<Vec<Violation>, String> {
    let mut files = Vec::new();
    for dir in ["src", "tests", "benches"] {
        collect_rs(&rust_root.join(dir), &mut files)?;
    }
    files.sort();

    let mut sources: BTreeMap<String, String> = BTreeMap::new();
    for path in &files {
        let rel = path
            .strip_prefix(rust_root)
            .map_err(|e| e.to_string())?
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        sources.insert(rel, src);
    }

    let mut out = Vec::new();
    for (rel, src) in &sources {
        out.extend(check_unsafe_safety(rel, src));
        out.extend(check_hotpath(rel, src));
    }

    // Rule 3 inputs: the dispatch-table struct and the three ISA files
    // that must each build a complete table.
    const STRUCT_FILE: &str = "src/kernels/isa.rs";
    const TABLE_FILES: &[&str] =
        &["src/kernels/isa.rs", "src/kernels/simd_avx2.rs", "src/kernels/simd_neon.rs"];
    match sources.get(STRUCT_FILE) {
        None => out.push(infra(STRUCT_FILE, "kernel dispatch file is missing".to_string())),
        Some(struct_src) => {
            let mut tables: Vec<(&str, &str)> = Vec::new();
            for &f in TABLE_FILES {
                match sources.get(f) {
                    Some(s) => tables.push((f, s.as_str())),
                    None => out.push(infra(f, "ISA kernel file is missing".to_string())),
                }
            }
            out.extend(check_kernel_parity((STRUCT_FILE, struct_src), &tables));
        }
    }

    // Rule 4 inputs: the gate binary's default list and the baseline.
    const GATE_FILE: &str = "src/bin/bench_gate.rs";
    match sources.get(GATE_FILE) {
        None => out.push(infra(GATE_FILE, "bench gate binary is missing".to_string())),
        Some(gate_src) => match parse_gate_default(gate_src) {
            None => out.push(infra(
                GATE_FILE,
                "could not locate the `get_or(\"gate\", ..)` default".to_string(),
            )),
            Some(gate) => {
                let baseline_path = rust_root
                    .parent()
                    .map(|p| p.join("BENCH_baseline.json"))
                    .unwrap_or_else(|| PathBuf::from("BENCH_baseline.json"));
                match fs::read_to_string(&baseline_path) {
                    Err(e) => out.push(infra(
                        "BENCH_baseline.json",
                        format!("baseline unreadable at {}: {e}", baseline_path.display()),
                    )),
                    Ok(doc) => {
                        let names = json_bench_names(&doc);
                        let fallback: Vec<String> = sources
                            .iter()
                            .filter(|(rel, _)| rel.starts_with("benches/"))
                            .flat_map(|(_, s)| string_literals(s))
                            .collect();
                        out.extend(check_bench_gate(GATE_FILE, &gate, &names, &fallback));
                    }
                }
            }
        },
    }

    out.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a hot-path-annotated source. Assembled from pieces so no
    /// line of this test file is itself the exact annotation line.
    fn hotpath_src(body: &str) -> String {
        let mut s = String::from("//! demo module\n//! lint: ");
        s.push_str("hotpath\n\n");
        s.push_str(body);
        s
    }

    fn rules_of(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    // -- mask ---------------------------------------------------------------

    #[test]
    fn mask_blanks_comments_strings_and_chars() {
        let src = "let a = \"unsafe\"; // unsafe here\nlet b = 'x';\nlet c = unsafe_name;\n";
        let m = mask(src);
        assert_eq!(m.lines().count(), src.lines().count());
        assert!(!m.contains("unsafe here"));
        assert!(!m.contains("\"unsafe\""));
        assert!(!m.contains('x'), "char literal content must be blanked: {m}");
        assert!(m.contains("unsafe_name"), "code identifiers survive: {m}");
        assert!(word_occurrences(&m, "unsafe").is_empty());
    }

    #[test]
    fn mask_handles_raw_strings_nested_comments_lifetimes() {
        let src = "let r = r#\"quoted \"unsafe\" text\"#;\n/* outer /* unsafe */ still */\nfn f<'a>(x: &'a u32) -> &'a u32 { x }\n";
        let m = mask(src);
        assert_eq!(m.lines().count(), src.lines().count());
        assert!(word_occurrences(&m, "unsafe").is_empty(), "{m}");
        assert!(m.contains("&'a u32"), "lifetimes pass through: {m}");
    }

    #[test]
    fn mask_keeps_newlines_in_string_continuations() {
        // A `\` line-continuation inside a string escapes the newline;
        // blanking it away would shift every later line number.
        let src = "let m = \"line one \\\n   continued\";\nlet after = token;\n";
        let m = mask(src);
        assert_eq!(m.lines().count(), src.lines().count());
        let at = m.find("after").expect("code after the string survives");
        assert_eq!(line_of(&m, at), 3);
    }

    // -- unsafe-safety ------------------------------------------------------

    #[test]
    fn undocumented_unsafe_block_is_flagged() {
        let src = "fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
        let vs = check_unsafe_safety("x.rs", src);
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].line, 2);
        assert_eq!(vs[0].rule, "unsafe-safety");
    }

    #[test]
    fn safety_comment_same_line_or_above_passes() {
        let same = "fn f(p: *const u32) -> u32 {\n    unsafe { *p } // SAFETY: caller checked\n}\n";
        assert!(check_unsafe_safety("x.rs", same).is_empty());
        let above = "fn f(p: *const u32) -> u32 {\n    // SAFETY: caller checked\n    unsafe { *p }\n}\n";
        assert!(check_unsafe_safety("x.rs", above).is_empty());
    }

    #[test]
    fn unsafe_fn_needs_safety_doc_section() {
        let bad = "unsafe fn f(p: *const u32) -> u32 {\n    *p\n}\n";
        assert_eq!(check_unsafe_safety("x.rs", bad).len(), 1);
        let good = "/// # Safety\n///\n/// `p` must be valid.\n#[inline]\nunsafe fn f(p: *const u32) -> u32 {\n    *p\n}\n";
        assert!(check_unsafe_safety("x.rs", good).is_empty());
    }

    #[test]
    fn unsafe_fn_pointer_type_is_exempt() {
        let src = "struct V {\n    call: unsafe fn(*const (), usize),\n}\n";
        assert!(check_unsafe_safety("x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_in_comments_and_strings_is_ignored() {
        let src = "// unsafe is discussed here\nlet s = \"unsafe { }\";\n";
        assert!(check_unsafe_safety("x.rs", src).is_empty());
    }

    // -- hotpath ------------------------------------------------------------

    #[test]
    fn hotpath_fires_only_in_annotated_files() {
        let body = "pub fn f() -> Vec<u32> {\n    let v = vec![1, 2, 3];\n    v\n}\n";
        assert!(check_hotpath("x.rs", body).is_empty(), "unannotated file is exempt");
        let vs = check_hotpath("x.rs", &hotpath_src(body));
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "hotpath");
    }

    #[test]
    fn hotpath_waiver_and_test_region_are_exempt() {
        let body = "pub fn f() -> Vec<u32> {\n    // lint: allow(hotpath) — constructor only\n    let v = vec![1];\n    v\n}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let _ = vec![0].to_vec();\n    }\n}\n";
        let vs = check_hotpath("x.rs", &hotpath_src(body));
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn hotpath_catches_clock_and_alloc_tokens() {
        let body = "pub fn f(x: Option<u32>) -> String {\n    let t = Instant::now();\n    let v = x.unwrap();\n    format!(\"{v} {t:?}\")\n}\n";
        let vs = check_hotpath("x.rs", &hotpath_src(body));
        assert_eq!(vs.len(), 3, "{vs:?}");
    }

    // -- kernel-parity ------------------------------------------------------

    const STRUCT_SRC: &str = "pub struct KernelTable {\n    pub name: &'static str,\n    pub dot_f32: fn(&[f32], &[f32]) -> f32,\n}\n";

    #[test]
    fn parity_passes_on_exact_field_match() {
        let table = "pub static T: KernelTable = KernelTable {\n    name: \"t\",\n    dot_f32: d,\n};\n";
        let vs = check_kernel_parity(("s.rs", STRUCT_SRC), &[("t.rs", table)]);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn parity_flags_missing_field_and_rest_defaulting() {
        let missing = "pub static T: KernelTable = KernelTable { name: \"t\" };\n";
        let vs = check_kernel_parity(("s.rs", STRUCT_SRC), &[("t.rs", missing)]);
        assert_eq!(rules_of(&vs), vec!["kernel-parity"], "{vs:?}");
        assert!(vs[0].message.contains("dot_f32"), "{vs:?}");

        let rest = "pub static T: KernelTable = KernelTable { name: \"t\", ..SCALAR };\n";
        let vs = check_kernel_parity(("s.rs", STRUCT_SRC), &[("t.rs", rest)]);
        assert_eq!(rules_of(&vs), vec!["kernel-parity"], "{vs:?}");
        assert!(vs[0].message.contains(".."), "{vs:?}");
    }

    #[test]
    fn parity_requires_an_initializer_and_skips_non_initializers() {
        // Return types, references, and ascriptions are not literals.
        let none = "fn best() -> &'static KernelTable {\n    todo!()\n}\nfn take(t: &KernelTable) {}\n";
        let vs = check_kernel_parity(("s.rs", STRUCT_SRC), &[("t.rs", none)]);
        assert_eq!(rules_of(&vs), vec!["kernel-parity"], "{vs:?}");
        assert!(vs[0].message.contains("no `KernelTable` initializer"), "{vs:?}");
    }

    // -- bench-gate ---------------------------------------------------------

    #[test]
    fn gate_default_is_extracted() {
        let src = "let gate = args.get_or(\"gate\", \"fused,gemm_w4a8,simd/\");\n";
        assert_eq!(parse_gate_default(src).as_deref(), Some("fused,gemm_w4a8,simd/"));
    }

    #[test]
    fn gate_substrings_checked_against_names_then_fallback() {
        let names = vec!["simd/dot/64".to_string(), "fused_decode".to_string()];
        assert!(check_bench_gate("g.rs", "fused,simd/", &names, &[]).is_empty());
        let vs = check_bench_gate("g.rs", "fused,nope", &names, &[]);
        assert_eq!(rules_of(&vs), vec!["bench-gate"], "{vs:?}");

        // Placeholder baseline (no names) → benches literals cover.
        let lits = vec!["simd/dot/{n}".to_string()];
        assert!(check_bench_gate("g.rs", "simd/", &[], &lits).is_empty());
        assert_eq!(check_bench_gate("g.rs", "gemm", &[], &lits).len(), 1);
    }

    #[test]
    fn json_names_and_string_literals_are_extracted() {
        let doc = "{\"benchmarks\":[{\"name\": \"simd/dot/64\"},{\"name\":\"fused\"}]}";
        assert_eq!(json_bench_names(doc), vec!["simd/dot/64", "fused"]);
        assert!(json_bench_names("{\"benchmarks\":[]}").is_empty());

        let src = "// \"not this\"\nlet a = \"fused_{n}\";\nlet b = r#\"raw/name\"#;\nlet c = 'q';\n";
        let lits = string_literals(src);
        assert_eq!(lits, vec!["fused_{n}", "raw/name"]);
    }
}
