//! Deterministic fault injection for the serving loop.
//!
//! A [`FaultPlan`] names faults to inject at exact points of a serve run
//! so the fault-tolerance paths (panic containment, preemption/requeue,
//! NaN detection) can be exercised deterministically in tests and from
//! the CLI (`swiftkv serve --faults ...`). Three fault kinds:
//!
//! - `panic@r<ID>:s<STEP>` — the lane serving request `ID` panics on the
//!   step that would sample its `STEP`-th generated token (`s0` is the
//!   final prefill chunk's sample). The server must contain the panic to
//!   that lane: the request fails, its KV blocks are reclaimed, the lane
//!   is recycled, and co-batched lanes keep bit-exact outputs.
//! - `nan@r<ID>:s<STEP>` — same trigger point, but instead of panicking
//!   the lane's newest KV rows are poisoned with NaN, driving the lane's
//!   logits non-finite. The server's sampler must detect and fail the
//!   request rather than emit garbage tokens. (Effective in `DesktopF32`
//!   numerics; the Q15.17 mirror saturates NaN away, which is itself the
//!   accelerator datapath's defense.)
//! - `oom@i<ITER>` — from iteration `ITER` on, the server's KV-capacity
//!   precheck sees zero free blocks, forcing the preemption path. The
//!   fault stays armed until it actually causes a preemption (an
//!   iteration where no lane asks for a new block is a no-op), then
//!   disarms.
//! - `disconnect@r<ID>:s<STEP>` — after request `ID` streams its
//!   `STEP`-th generated token, its client vanishes (the engine marks
//!   the event sink dead, exactly as if the `PendingRequest` or SSE
//!   socket dropped). The lane must be cancelled at the next iteration
//!   boundary with its KV blocks reclaimed and co-batched survivors
//!   bit-exact.
//! - `slowclient@r<ID>` — request `ID`'s client stops consuming events:
//!   the engine treats its bounded stream as full from the first token
//!   on, driving the slow-client back-pressure cancellation path.
//! - `burst@i<ITER>[:n<COUNT>]` — at iteration `ITER`, `COUNT` synthetic
//!   requests (default 4× the lane count) slam the admission queue in
//!   one iteration, driving the queue-depth shedding path without an
//!   external load generator.
//!
//! Every fault fires **at most once** (atomic fired flags), so a plan is
//! a finite perturbation: the run must converge back to normal service.
//! Plans come from an explicit spec string or from a seed
//! ([`FaultPlan::seeded`], env `SWIFTKV_FAULT_SEED`) that draws a small
//! random plan through [`crate::util::Rng`] — the CI fault matrix runs
//! the same tests under several seeds.

use crate::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};

/// What a per-lane fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic inside the lane's step (contained by the server).
    LanePanic,
    /// Poison the lane's newest KV rows with NaN before the step.
    NanActivations,
    /// The request's client vanishes mid-stream (cancellation path).
    ClientDisconnect,
    /// The request's client stops consuming its event stream
    /// (slow-client back-pressure path). Step-agnostic.
    SlowClient,
}

/// One per-lane fault: fires when request `request_id` reaches the step
/// that samples its `step`-th generated token.
#[derive(Debug)]
struct LaneFault {
    kind: FaultKind,
    request_id: u64,
    step: usize,
    fired: AtomicBool,
}

/// One forced pool-exhaustion window, armed from `iteration` until it
/// causes a preemption.
#[derive(Debug)]
struct OomFault {
    iteration: u64,
    fired: AtomicBool,
}

/// One synthetic admission burst: `n` requests injected at `iteration`
/// (`n == 0` → the engine substitutes 4× its lane count).
#[derive(Debug)]
struct BurstFault {
    iteration: u64,
    n: usize,
    fired: AtomicBool,
}

/// A deterministic set of faults to inject into one serve run.
///
/// Interior mutability (atomic fired flags) lets the server consult the
/// plan from `&self` mid-run; every fault fires at most once.
#[derive(Debug, Default)]
pub struct FaultPlan {
    lane_faults: Vec<LaneFault>,
    oom_faults: Vec<OomFault>,
    burst_faults: Vec<BurstFault>,
}

impl Clone for FaultPlan {
    fn clone(&self) -> Self {
        FaultPlan {
            lane_faults: self
                .lane_faults
                .iter()
                .map(|f| LaneFault {
                    kind: f.kind,
                    request_id: f.request_id,
                    step: f.step,
                    fired: AtomicBool::new(f.fired.load(Ordering::Relaxed)),
                })
                .collect(),
            oom_faults: self
                .oom_faults
                .iter()
                .map(|f| OomFault {
                    iteration: f.iteration,
                    fired: AtomicBool::new(f.fired.load(Ordering::Relaxed)),
                })
                .collect(),
            burst_faults: self
                .burst_faults
                .iter()
                .map(|f| BurstFault {
                    iteration: f.iteration,
                    n: f.n,
                    fired: AtomicBool::new(f.fired.load(Ordering::Relaxed)),
                })
                .collect(),
        }
    }
}

impl FaultPlan {
    /// Parse a comma-separated spec: `panic@r<ID>:s<STEP>`,
    /// `nan@r<ID>:s<STEP>`, `disconnect@r<ID>:s<STEP>`,
    /// `slowclient@r<ID>`, `oom@i<ITER>`, `burst@i<ITER>[:n<COUNT>]`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, at) = entry
                .split_once('@')
                .ok_or_else(|| format!("fault '{entry}': expected '<kind>@<where>'"))?;
            match kind {
                "panic" | "nan" | "disconnect" => {
                    let (r, s) = at.split_once(':').ok_or_else(|| {
                        format!("fault '{entry}': expected '{kind}@r<ID>:s<STEP>'")
                    })?;
                    let request_id = r
                        .strip_prefix('r')
                        .and_then(|n| n.parse::<u64>().ok())
                        .ok_or_else(|| format!("fault '{entry}': bad request id '{r}'"))?;
                    let step = s
                        .strip_prefix('s')
                        .and_then(|n| n.parse::<usize>().ok())
                        .ok_or_else(|| format!("fault '{entry}': bad step '{s}'"))?;
                    plan.lane_faults.push(LaneFault {
                        kind: match kind {
                            "panic" => FaultKind::LanePanic,
                            "nan" => FaultKind::NanActivations,
                            _ => FaultKind::ClientDisconnect,
                        },
                        request_id,
                        step,
                        fired: AtomicBool::new(false),
                    });
                }
                "slowclient" => {
                    let request_id = at
                        .strip_prefix('r')
                        .and_then(|n| n.parse::<u64>().ok())
                        .ok_or_else(|| format!("fault '{entry}': expected 'slowclient@r<ID>'"))?;
                    plan.lane_faults.push(LaneFault {
                        kind: FaultKind::SlowClient,
                        request_id,
                        step: 0,
                        fired: AtomicBool::new(false),
                    });
                }
                "oom" => {
                    let iteration = at
                        .strip_prefix('i')
                        .and_then(|n| n.parse::<u64>().ok())
                        .ok_or_else(|| format!("fault '{entry}': expected 'oom@i<ITER>'"))?;
                    plan.oom_faults.push(OomFault {
                        iteration,
                        fired: AtomicBool::new(false),
                    });
                }
                "burst" => {
                    let (i, n) = match at.split_once(':') {
                        Some((i, n)) => {
                            let count = n
                                .strip_prefix('n')
                                .and_then(|c| c.parse::<usize>().ok())
                                .ok_or_else(|| {
                                    format!("fault '{entry}': bad burst count '{n}'")
                                })?;
                            (i, count)
                        }
                        None => (at, 0),
                    };
                    let iteration = i
                        .strip_prefix('i')
                        .and_then(|v| v.parse::<u64>().ok())
                        .ok_or_else(|| {
                            format!("fault '{entry}': expected 'burst@i<ITER>[:n<COUNT>]'")
                        })?;
                    plan.burst_faults.push(BurstFault {
                        iteration,
                        n,
                        fired: AtomicBool::new(false),
                    });
                }
                other => return Err(format!("fault '{entry}': unknown kind '{other}'")),
            }
        }
        Ok(plan)
    }

    /// A small random plan drawn deterministically from `seed`: one or
    /// two lane faults (panic or NaN) aimed at requests `0..8`, steps
    /// `0..4`, plus — for odd seeds — a forced pool exhaustion in the
    /// first iterations. Whether a given fault actually fires depends on
    /// the workload (a fault aimed at a request that never reaches its
    /// step is a no-op); the server must survive either way.
    pub fn seeded(seed: u64) -> FaultPlan {
        let mut rng = Rng::seed_from_u64(seed ^ 0xFA_17_5E_ED);
        let mut plan = FaultPlan::default();
        let n_lane = 1 + rng.gen_range(0, 2);
        for _ in 0..n_lane {
            plan.lane_faults.push(LaneFault {
                kind: if rng.gen_range(0, 2) == 0 {
                    FaultKind::LanePanic
                } else {
                    FaultKind::NanActivations
                },
                request_id: rng.gen_range(0, 8) as u64,
                step: rng.gen_range(0, 4),
                fired: AtomicBool::new(false),
            });
        }
        if seed % 2 == 1 {
            plan.oom_faults.push(OomFault {
                iteration: rng.gen_range(1, 8) as u64,
                fired: AtomicBool::new(false),
            });
        }
        // A third of seeds also drop a client mid-stream (drawn after
        // the existing faults so earlier seeds keep their exact plans).
        // Never a burst: seeded plans run under workloads that assert on
        // the session count, and bursts inject extra sessions.
        if seed % 3 == 2 {
            plan.lane_faults.push(LaneFault {
                kind: FaultKind::ClientDisconnect,
                request_id: rng.gen_range(0, 8) as u64,
                step: rng.gen_range(0, 4),
                fired: AtomicBool::new(false),
            });
        }
        plan
    }

    /// Plan from the environment: `SWIFTKV_FAULTS` (explicit spec) wins,
    /// else `SWIFTKV_FAULT_SEED` (seeded plan), else `None`.
    pub fn from_env() -> Result<Option<FaultPlan>, String> {
        if let Ok(spec) = std::env::var("SWIFTKV_FAULTS") {
            if !spec.trim().is_empty() {
                return FaultPlan::parse(&spec).map(Some);
            }
        }
        if let Ok(seed) = std::env::var("SWIFTKV_FAULT_SEED") {
            let seed: u64 = seed
                .trim()
                .parse()
                .map_err(|_| format!("SWIFTKV_FAULT_SEED: bad integer '{seed}'"))?;
            return Ok(Some(FaultPlan::seeded(seed)));
        }
        Ok(None)
    }

    /// No faults at all?
    pub fn is_empty(&self) -> bool {
        self.lane_faults.is_empty() && self.oom_faults.is_empty() && self.burst_faults.is_empty()
    }

    /// Check-and-fire a per-lane *step* fault (panic / NaN): the unfired
    /// fault (if any) aimed at `request_id`'s `step`-th sample. Marks it
    /// fired, so each fault perturbs exactly one step. Client-behavior
    /// faults (disconnect / slow client) have their own fire methods —
    /// they perturb the sink, not the step.
    pub fn fire_lane_fault(&self, request_id: u64, step: usize) -> Option<FaultKind> {
        for f in &self.lane_faults {
            if matches!(f.kind, FaultKind::LanePanic | FaultKind::NanActivations)
                && f.request_id == request_id
                && f.step == step
                && f.fired
                    .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
            {
                return Some(f.kind);
            }
        }
        None
    }

    /// Check-and-fire a client disconnect: true when request
    /// `request_id` has streamed `step` tokens and its plan says the
    /// client now vanishes. Fires at most once.
    pub fn fire_disconnect(&self, request_id: u64, step: usize) -> bool {
        self.lane_faults.iter().any(|f| {
            f.kind == FaultKind::ClientDisconnect
                && f.request_id == request_id
                && f.step == step
                && f.fired
                    .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
        })
    }

    /// Check-and-fire a slow-client stall for `request_id` (step
    /// agnostic: the client is slow from its first token). Fires at most
    /// once.
    pub fn fire_slowclient(&self, request_id: u64) -> bool {
        self.lane_faults.iter().any(|f| {
            f.kind == FaultKind::SlowClient
                && f.request_id == request_id
                && f.fired
                    .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
        })
    }

    /// Check-and-fire an admission burst armed at `iteration`: the
    /// number of synthetic requests to inject this iteration (`0` means
    /// "engine picks", conventionally 4× its lane count). Fires at most
    /// once per burst fault.
    pub fn fire_burst(&self, iteration: u64) -> Option<usize> {
        for f in &self.burst_faults {
            if iteration >= f.iteration
                && f.fired
                    .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
            {
                return Some(f.n);
            }
        }
        None
    }

    /// Is a forced pool exhaustion armed at `iteration`? (Armed = its
    /// start iteration has passed and it has not yet caused a
    /// preemption.)
    pub fn oom_armed(&self, iteration: u64) -> bool {
        self.oom_faults
            .iter()
            .any(|f| iteration >= f.iteration && !f.fired.load(Ordering::Relaxed))
    }

    /// Disarm the armed pool-exhaustion fault after it caused a
    /// preemption.
    pub fn oom_fired(&self, iteration: u64) {
        for f in &self.oom_faults {
            if iteration >= f.iteration {
                f.fired.store(true, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        let p = FaultPlan::parse("panic@r2:s5, nan@r1:s0 ,oom@i10").unwrap();
        assert_eq!(p.lane_faults.len(), 2);
        assert_eq!(p.oom_faults.len(), 1);
        assert_eq!(p.fire_lane_fault(2, 5), Some(FaultKind::LanePanic));
        assert_eq!(p.fire_lane_fault(1, 0), Some(FaultKind::NanActivations));
        assert!(p.oom_armed(10) && p.oom_armed(11) && !p.oom_armed(9));
    }

    #[test]
    fn faults_fire_at_most_once() {
        let p = FaultPlan::parse("panic@r0:s1").unwrap();
        assert_eq!(p.fire_lane_fault(0, 1), Some(FaultKind::LanePanic));
        assert_eq!(p.fire_lane_fault(0, 1), None, "second fire must be a no-op");
        let p = FaultPlan::parse("oom@i3").unwrap();
        assert!(p.oom_armed(3));
        p.oom_fired(3);
        assert!(!p.oom_armed(4), "oom disarms after causing a preemption");
    }

    #[test]
    fn misses_are_no_ops() {
        let p = FaultPlan::parse("panic@r7:s2").unwrap();
        assert_eq!(p.fire_lane_fault(7, 1), None);
        assert_eq!(p.fire_lane_fault(6, 2), None);
        assert_eq!(p.fire_lane_fault(7, 2), Some(FaultKind::LanePanic));
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in ["panic", "panic@x1:s2", "panic@r1", "oom@7", "boom@i1", "nan@r1:sx"] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' should not parse");
        }
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn seeded_plans_are_deterministic_and_nonempty() {
        for seed in [0u64, 1, 0xC0FFEE, 0xD15EA5E] {
            let a = FaultPlan::seeded(seed);
            let b = FaultPlan::seeded(seed);
            assert!(!a.is_empty());
            assert_eq!(a.lane_faults.len(), b.lane_faults.len());
            for (x, y) in a.lane_faults.iter().zip(&b.lane_faults) {
                assert_eq!((x.kind, x.request_id, x.step), (y.kind, y.request_id, y.step));
            }
            assert_eq!(a.oom_faults.len(), b.oom_faults.len());
        }
        // odd seeds arm a pool-exhaustion fault
        assert!(!FaultPlan::seeded(1).oom_faults.is_empty());
    }

    #[test]
    fn clone_preserves_fired_state() {
        let p = FaultPlan::parse("panic@r0:s0").unwrap();
        assert!(p.fire_lane_fault(0, 0).is_some());
        let q = p.clone();
        assert_eq!(q.fire_lane_fault(0, 0), None, "clone keeps the fired flag");
    }

    #[test]
    fn parses_overload_kinds() {
        let p = FaultPlan::parse("disconnect@r3:s2,slowclient@r5,burst@i4:n12,burst@i9").unwrap();
        assert!(!p.is_empty());
        assert!(p.fire_disconnect(3, 2));
        assert!(!p.fire_disconnect(3, 2), "disconnect fires once");
        assert!(p.fire_slowclient(5));
        assert!(!p.fire_slowclient(5), "slowclient fires once");
        assert_eq!(p.fire_burst(4), Some(12));
        assert_eq!(p.fire_burst(9), Some(0), "bare burst defers count to the engine");
        assert_eq!(p.fire_burst(10), None, "both bursts spent");
    }

    #[test]
    fn overload_kind_misses_are_no_ops() {
        let p = FaultPlan::parse("disconnect@r3:s2,slowclient@r5,burst@i4").unwrap();
        assert!(!p.fire_disconnect(3, 1), "wrong step");
        assert!(!p.fire_disconnect(4, 2), "wrong request");
        assert!(!p.fire_slowclient(6), "wrong request");
        assert_eq!(p.fire_burst(3), None, "burst not yet armed");
        // a disconnect never leaks through the panic/nan fire path
        assert_eq!(p.fire_lane_fault(3, 2), None);
        assert!(p.fire_disconnect(3, 2), "still armed after the step-fault miss");
    }

    #[test]
    fn rejects_malformed_overload_specs() {
        for bad in [
            "disconnect@r1",
            "disconnect@i1:s2",
            "slowclient@s1",
            "slowclient@r1:s2",
            "burst@r1",
            "burst@i1:x4",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn seeded_disconnect_draw_is_deterministic_and_appended() {
        for seed in [2u64, 5, 8, 11] {
            let a = FaultPlan::seeded(seed);
            let b = FaultPlan::seeded(seed);
            assert_eq!(a.lane_faults.len(), b.lane_faults.len());
            assert_eq!(
                a.lane_faults.last().map(|f| f.kind),
                Some(FaultKind::ClientDisconnect),
                "seed {seed} (≡2 mod 3) appends a disconnect"
            );
            assert!(
                a.burst_faults.is_empty(),
                "seeded plans never draw bursts (session-count contract)"
            );
        }
        for seed in [0u64, 1, 3, 13, 21, 34] {
            assert!(
                FaultPlan::seeded(seed)
                    .lane_faults
                    .iter()
                    .all(|f| f.kind != FaultKind::ClientDisconnect),
                "seed {seed} (≢2 mod 3) keeps its pre-overload plan"
            );
        }
    }
}
