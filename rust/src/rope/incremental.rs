//! Decoder-specialized incremental RoPE (Eq. 11) — the SKV unit's RoPE
//! block (Fig. 6).
//!
//! State per sequence: `(cos mθ_i, sin mθ_i)` for the last processed
//! position `m`, plus the constants `a_i = cos θ_i`, `b_i = sin θ_i`.
//! Advancing one token is a single angle addition per channel pair —
//! the four-multiplier network of Fig. 6 — after which the new token's
//! q/k pairs are rotated with the updated values.

use super::standard::{rope_apply_cached, rope_freqs};

/// Per-sequence incremental RoPE state.
#[derive(Debug, Clone)]
pub struct RopeState {
    /// Constants a_i = cos θ_i (stored in the SKV unit at configuration).
    a: Vec<f32>,
    /// Constants b_i = sin θ_i.
    b: Vec<f32>,
    /// Cached cos(mθ_i) for the last processed position.
    pub cos: Vec<f32>,
    /// Cached sin(mθ_i).
    pub sin: Vec<f32>,
    /// Last processed position m (`None` before the first token).
    pub pos: Option<u64>,
}

impl RopeState {
    /// Fresh state for a head dimension `d` (and RoPE base). The cache is
    /// seeded one step *before* position 0 — cos(−θ) = a, sin(−θ) = −b —
    /// so the first `advance()` lands exactly on position 0.
    pub fn new(d: usize, base: f64) -> Self {
        let freqs = rope_freqs(d, base);
        let a: Vec<f32> = freqs.iter().map(|w| w.cos() as f32).collect();
        let b: Vec<f32> = freqs.iter().map(|w| w.sin() as f32).collect();
        let cos = a.clone();
        let sin = b.iter().map(|x| -x).collect();
        RopeState {
            a,
            b,
            cos,
            sin,
            pos: None,
        }
    }

    /// Rewind to the pre-position-0 seed state in place — identical to a
    /// fresh [`RopeState::new`] but without allocating (lane recycling in
    /// the serving path reuses the four buffers).
    pub fn reset(&mut self) {
        self.cos.copy_from_slice(&self.a);
        for (s, &b) in self.sin.iter_mut().zip(&self.b) {
            *s = -b;
        }
        self.pos = None;
    }

    /// One angle-addition step (Eq. 11's recurrence core):
    /// `cos((m+1)θ) = cos(mθ)·a − sin(mθ)·b`,
    /// `sin((m+1)θ) = cos(mθ)·b + sin(mθ)·a`.
    pub fn advance(&mut self) {
        for i in 0..self.cos.len() {
            let (c, s) = (self.cos[i], self.sin[i]);
            self.cos[i] = c * self.a[i] - s * self.b[i];
            self.sin[i] = c * self.b[i] + s * self.a[i];
        }
        self.pos = Some(self.pos.map_or(0, |p| p + 1));
    }

    /// Advance to the next position and rotate the new token's `q` and
    /// `k` — the full Eq. (11) step. Returns `(q', k')`; `k'` is what gets
    /// written to the KV cache (already position-encoded).
    pub fn rotate_next(&mut self, q: &[f32], k: &[f32]) -> (Vec<f32>, Vec<f32>) {
        assert_eq!(q.len(), 2 * self.cos.len());
        assert_eq!(k.len(), 2 * self.cos.len());
        self.advance();
        (
            rope_apply_cached(q, &self.cos, &self.sin),
            rope_apply_cached(k, &self.cos, &self.sin),
        )
    }

    /// Renormalize the (cos, sin) pairs onto the unit circle. The FPGA
    /// never does this (FXP32 drift over realistic contexts is below
    /// resolution — see the drift test); exposed for very long sessions.
    pub fn renormalize(&mut self) {
        for i in 0..self.cos.len() {
            let n = self.cos[i].hypot(self.sin[i]);
            if n > 0.0 {
                self.cos[i] /= n;
                self.sin[i] /= n;
            }
        }
    }

    pub fn dim(&self) -> usize {
        2 * self.cos.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rope::standard::rope_standard;

    const BASE: f64 = 10000.0;

    #[test]
    fn first_advance_hits_position_zero() {
        let mut st = RopeState::new(8, BASE);
        st.advance();
        assert_eq!(st.pos, Some(0));
        for (i, (&c, &s)) in st.cos.iter().zip(&st.sin).enumerate() {
            assert!((c - 1.0).abs() < 1e-6, "cos[{i}] = {c}");
            assert!(s.abs() < 1e-6, "sin[{i}] = {s}");
        }
    }

    #[test]
    fn reset_matches_fresh_state() {
        let mut st = RopeState::new(16, BASE);
        for _ in 0..37 {
            st.advance();
        }
        st.reset();
        let fresh = RopeState::new(16, BASE);
        assert_eq!(st.cos, fresh.cos);
        assert_eq!(st.sin, fresh.sin);
        assert_eq!(st.pos, None);
        st.advance();
        assert_eq!(st.pos, Some(0));
    }

    #[test]
    fn rotate_next_matches_direct_rope() {
        let d = 32;
        let q: Vec<f32> = (0..d).map(|i| (i as f32 * 0.11).sin()).collect();
        let k: Vec<f32> = (0..d).map(|i| (i as f32 * 0.07).cos()).collect();
        let mut st = RopeState::new(d, BASE);
        for m in 0..50u64 {
            let (qr, kr) = st.rotate_next(&q, &k);
            let qd = rope_standard(&q, m, BASE);
            let kd = rope_standard(&k, m, BASE);
            for (a, b) in qr.iter().zip(&qd) {
                assert!((a - b).abs() < 1e-4, "q mismatch at m={m}");
            }
            for (a, b) in kr.iter().zip(&kd) {
                assert!((a - b).abs() < 1e-4, "k mismatch at m={m}");
            }
        }
    }

    #[test]
    fn drift_over_long_decode_is_negligible() {
        // 16k steps of the f32 recurrence vs direct trig: the error stays
        // far below attention-relevant scales (paper's implicit claim that
        // the recurrence is safe for long contexts).
        let d = 64;
        let mut st = RopeState::new(d, BASE);
        for _ in 0..16384 {
            st.advance();
        }
        let m = st.pos.unwrap();
        let freqs = rope_freqs(d, BASE);
        for (i, w) in freqs.iter().enumerate() {
            let want_c = ((m as f64) * w).cos() as f32;
            let want_s = ((m as f64) * w).sin() as f32;
            assert!(
                (st.cos[i] - want_c).abs() < 5e-3,
                "cos drift at i={i}: {} vs {want_c}",
                st.cos[i]
            );
            assert!(
                (st.sin[i] - want_s).abs() < 5e-3,
                "sin drift at i={i}: {} vs {want_s}",
                st.sin[i]
            );
        }
    }

    #[test]
    fn unit_circle_preserved() {
        let mut st = RopeState::new(16, BASE);
        for _ in 0..4096 {
            st.advance();
        }
        for i in 0..st.cos.len() {
            let n = st.cos[i].hypot(st.sin[i]);
            assert!((n - 1.0).abs() < 1e-3, "norm {n} at {i}");
        }
    }

    #[test]
    fn renormalize_restores_unit_norm() {
        let mut st = RopeState::new(8, BASE);
        for _ in 0..100000 {
            st.advance();
        }
        st.renormalize();
        for i in 0..st.cos.len() {
            assert!((st.cos[i].hypot(st.sin[i]) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn only_new_token_rotated_cached_keys_valid() {
        // simulate the paper's cache discipline: keys rotated at their own
        // positions and stored; a later query still produces the correct
        // relative-position inner products.
        let d = 16;
        let k: Vec<f32> = (0..d).map(|i| (i as f32 * 0.19).sin()).collect();
        let q: Vec<f32> = (0..d).map(|i| (i as f32 * 0.23).cos()).collect();
        let mut st = RopeState::new(d, BASE);
        let mut cache: Vec<Vec<f32>> = Vec::new();
        for _ in 0..20 {
            let (_, kr) = st.rotate_next(&q, &k);
            cache.push(kr);
        }
        // query at position 19 (the state's current cos/sin)
        let q19 = rope_apply_cached(&q, &st.cos, &st.sin);
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        // compare against direct computation
        for (t, kc) in cache.iter().enumerate() {
            let want = dot(&rope_standard(&q, 19, BASE), &rope_standard(&k, t as u64, BASE));
            let got = dot(&q19, kc);
            assert!((got - want).abs() < 1e-3, "t={t}: {got} vs {want}");
        }
    }
}
