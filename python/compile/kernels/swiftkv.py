"""Layer-1 Pallas kernel: single-pass SwiftKV decode attention.

The FPGA pipeline of Fig. 2/3 maps to TPU-style Pallas as follows
(DESIGN.md §Hardware-Adaptation):

- the KV cache streams through VMEM in ``(block_k, d)`` tiles — the
  ``BlockSpec`` grid walk *is* the paper's "pipelined KV-cache reads", and
  the grid visits every tile exactly once (the single-pass property);
- the FPGA's update-part registers (mu, Z, Y) become VMEM scratch
  accumulators carried across grid steps;
- the per-token compare-and-select of Eqs. (6)/(7) becomes the associative
  blockwise form of the same recurrence: within a tile the block max plays
  the role of the incoming ``s_t`` stream's running max, and the
  ``alpha``-rescale of the carried (Z, Y) is identical to the
  ``s_t > mu`` branch of Eq. (7). With ``block_k=1`` the kernel degrades
  to the literal per-token recurrence.

The kernel is row-batched: ``R`` independent (head x sequence) rows are
processed by grid dimension 0, so the multi-head / multi-request case needs
no vmap. Per-row valid lengths support ragged batches.

Pallas runs ``interpret=True`` (environment contract: real-TPU lowering
emits Mosaic custom-calls the CPU PJRT client cannot execute).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 64
NEG_INF = -1e30


def _swiftkv_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref,
                    mu_ref, z_ref, y_ref, *, block_k: int, scale: float):
    """One (row, kv-block) grid step of the single-pass recurrence."""
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():  # reset carried state at the start of each row's scan
        mu_ref[...] = jnp.full_like(mu_ref, NEG_INF)
        z_ref[...] = jnp.zeros_like(z_ref)
        y_ref[...] = jnp.zeros_like(y_ref)

    q = q_ref[0, :]                       # [d]
    k = k_ref[0, :, :]                    # [block_k, d]
    v = v_ref[0, :, :]                    # [block_k, d]

    # Eq. (5): s_t = q k_t^T / sqrt(d), one tile of the score stream.
    s = (k @ q) * scale                   # [block_k]
    t = j * block_k + jax.lax.iota(jnp.int32, block_k)
    valid = t < lens_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    mu_prev = mu_ref[0, 0]
    z_prev = z_ref[0, 0]
    y_prev = y_ref[0, :]

    # Blockwise form of Eqs. (6)-(7): the tile max takes the role of the
    # incoming score; alpha rescales the carried accumulators when the max
    # grows, beta-weights fold the tile in. Exactly-once per (k_t, v_t).
    mu_tile = jnp.max(s)
    mu_new = jnp.maximum(mu_prev, mu_tile)
    alpha = jnp.exp(mu_prev - mu_new)               # in (0, 1]
    p = jnp.where(valid, jnp.exp(s - mu_new), 0.0)  # [block_k]
    z_new = alpha * z_prev + jnp.sum(p)
    y_new = alpha * y_prev + p @ v

    mu_ref[0, 0] = mu_new
    z_ref[0, 0] = z_new
    y_ref[0, :] = y_new

    @pl.when(j == nb - 1)
    def _finalize():  # Eq. (8): deferred one-time normalization
        o_ref[0, :] = y_new / z_new


@functools.partial(jax.jit, static_argnames=("block_k",))
def swiftkv_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      lens: jax.Array, *, block_k: int = DEFAULT_BLOCK_K
                      ) -> jax.Array:
    """Single-pass SwiftKV decode attention over row-batched KV caches.

    q: [R, d] queries (one per head x sequence row);
    k, v: [R, N, d] KV cache; lens: [R] int32 valid lengths (>= 1);
    returns [R, d] attention outputs.
    """
    r, d = q.shape
    n = k.shape[1]
    if n % block_k != 0:
        raise ValueError(f"context capacity {n} not divisible by block_k {block_k}")
    nb = n // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_swiftkv_kernel, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(r, nb),
        in_specs=[
            pl.BlockSpec((1,), lambda h, j: (h,)),          # lens
            pl.BlockSpec((1, d), lambda h, j: (h, 0)),      # q
            pl.BlockSpec((1, block_k, d), lambda h, j: (h, j, 0)),  # k
            pl.BlockSpec((1, block_k, d), lambda h, j: (h, j, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, d), lambda h, j: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),   # mu
            pltpu.VMEM((1, 1), jnp.float32),   # Z
            pltpu.VMEM((1, d), jnp.float32),   # Y
        ],
        interpret=True,
    )(lens, q, k, v)
