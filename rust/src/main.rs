//! `swiftkv` — leader binary: exhibit regeneration, accelerator
//! simulation and the decode serving demo, all from one CLI.
//!
//! ```text
//! swiftkv exhibits [--only fig7a|fig7b|table2|table3|table4|fig8a|fig8b|explut]
//! swiftkv simulate --model llama2-7b|chatglm-6b|llama3-8b|qwen3-8b --ctx 512
//! swiftkv serve    [--requests 16] [--batch 8] [--gap-ms 0] [--seed 0] [--kv-heads 8]
//!                  [--kv-block-len 16] [--kv-pool-blocks 0] [--prefill-chunk 8]
//!                  [--adaptive-prefill] [--prompt-len 0] [--workers 0] [--deadline-ms 0]
//!                  [--faults panic@r0:s1,oom@i4,disconnect@r2:s1,burst@i3:n16]
//!                  [--max-requeues 3] [--max-queue 0] [--drain-ms 5000]
//!                  [--listen 127.0.0.1:8080] [--serve-wall-ms 0] [--http-timeout-ms 5000]
//! swiftkv accuracy [--sequences 20] [--len 48]
//! ```
//!
//! With `--listen`, `serve` boots the continuous engine behind the
//! HTTP/SSE front door instead of draining a synthetic workload:
//! `POST /v1/generate` streams tokens as server-sent events, and
//! requests join the running batch mid-flight. `--max-queue` bounds the
//! admission queue (overflow is shed with `503 + Retry-After`),
//! `--drain-ms` bounds the graceful drain `Ctrl-C` triggers, and
//! `--http-timeout-ms` sets each connection's socket read/write
//! timeouts.

#[cfg(feature = "pjrt")]
use swiftkv::coordinator::{ServeOptions, Server};
use swiftkv::coordinator::{
    serve_http, CpuServer, FaultPlan, HttpServerConfig, ServeConfig, DEFAULT_PREFILL_CHUNK,
};
use swiftkv::model::{
    LlmConfig, NumericsMode, TinyModel, WeightStore, WorkloadGen, WorkloadSpec,
    DEFAULT_KV_BLOCK_LEN,
};
use swiftkv::report;
#[cfg(feature = "pjrt")]
use swiftkv::runtime::Engine;
use swiftkv::runtime::{artifacts_available, default_artifacts_dir};
use swiftkv::sim::{layer_sched, ArchConfig};
use swiftkv::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn model_by_name(name: &str) -> Result<LlmConfig, String> {
    Ok(match name {
        "llama2-7b" => LlmConfig::llama2_7b(),
        "chatglm-6b" => LlmConfig::chatglm_6b(),
        "llama3-8b" => LlmConfig::llama3_8b(),
        "qwen3-8b" => LlmConfig::qwen3_8b(),
        "tiny" => LlmConfig::tiny(),
        other => return Err(format!("unknown model '{other}'")),
    })
}

fn workload_spec(args: &Args, vocab: usize) -> Result<WorkloadSpec, String> {
    // --prompt-len N pins every request to an N-token prompt (TTFT
    // experiments with chunked prefill); 0 keeps the default 4–24 range
    let prompt_len = args.get_usize("prompt-len", 0)?;
    Ok(WorkloadSpec {
        num_requests: args.get_usize("requests", 16)?,
        vocab,
        prompt_len: if prompt_len > 0 {
            (prompt_len, prompt_len)
        } else {
            (4, 24)
        },
        gen_len: (8, 48),
        mean_gap_ms: args.get_f64("gap-ms", 0.0)?,
        deadline_ms: args.get_usize("deadline-ms", 0)? as u64,
        seed: args.get_usize("seed", 0)? as u64,
    })
}

#[cfg(feature = "pjrt")]
fn serve_pjrt(args: &Args) -> Result<(), String> {
    let eng = Engine::load(&default_artifacts_dir()).map_err(|e| e.to_string())?;
    let reqs = WorkloadGen::new(workload_spec(args, eng.manifest.vocab)?).generate();
    let batch = args.get_usize("batch", 8)?;
    let report = Server::new(
        &eng,
        ServeOptions {
            batch: Some(batch),
            max_iterations: 0,
            sim_model: LlmConfig::llama2_7b(),
        },
    )
    .serve(reqs)
    .map_err(|e| e.to_string())?;
    println!("{}", report.metrics.format_table());
    Ok(())
}

/// Serve over the pure-Rust CPU backend (fused decode kernels, lanes in
/// parallel). Falls back to a synthetic tiny model when the AOT
/// artifacts have not been built; `--kv-heads` picks its GQA shape
/// (8 = MHA, 2 = group-4 GQA, 1 = MQA).
fn serve_cpu(args: &Args) -> Result<(), String> {
    // the synthetic fallback model's query-head count; --kv-heads must
    // divide it (only meaningful when artifacts are absent)
    const SYNTH_HEADS: usize = 8;
    println!(
        "(kernel dispatch: {} microkernels — override with SWIFTKV_ISA)",
        swiftkv::kernels::isa::active_name()
    );
    let tm = if artifacts_available() {
        if args.get("kv-heads").is_some() {
            println!(
                "(--kv-heads applies only to the synthetic fallback — serving the AOT \
                 artifact model with its own head shape)"
            );
        }
        let ws = WeightStore::load(&default_artifacts_dir()).map_err(|e| e.to_string())?;
        TinyModel::load(&ws).map_err(|e| e.to_string())?
    } else {
        let kv_heads = args.get_usize("kv-heads", SYNTH_HEADS)?;
        if kv_heads == 0 || SYNTH_HEADS % kv_heads != 0 {
            return Err(format!("--kv-heads must divide {SYNTH_HEADS}, got {kv_heads}"));
        }
        println!(
            "(artifacts not built — serving the synthetic tiny model on the CPU backend, \
             {SYNTH_HEADS} query heads / {kv_heads} KV heads)"
        );
        TinyModel::synthetic(0, 512, 256, SYNTH_HEADS, kv_heads, 4, 1024, 512)
    };
    let lanes = args.get_usize("batch", 8)?;
    // paged-KV pool shape: tokens per block, and total blocks shared by
    // every lane (0 = worst case, all lanes at full context)
    let kv_block_len = args.get_usize("kv-block-len", DEFAULT_KV_BLOCK_LEN)?;
    if kv_block_len == 0 {
        return Err("--kv-block-len must be at least 1".into());
    }
    let kv_pool_blocks = args.get_usize("kv-pool-blocks", 0)?;
    // prompt tokens per lane per iteration through the fused chunked
    // prefill (0 = whole prompt in one step; 1 = legacy per-token)
    let prefill_chunk = args.get_usize("prefill-chunk", DEFAULT_PREFILL_CHUNK)?;
    // engine threads (serving thread + persistent pool workers);
    // 0 = one per available CPU, 1 = fully inline
    let workers = args.get_usize("workers", 0)?;
    // fault injection: --faults takes an explicit spec; otherwise the
    // SWIFTKV_FAULTS / SWIFTKV_FAULT_SEED environment is honoured
    let faults = match args.get("faults") {
        Some(spec) => Some(FaultPlan::parse(spec)?),
        None => FaultPlan::from_env()?,
    };
    if let Some(plan) = faults.as_ref().filter(|p| !p.is_empty()) {
        println!("(fault injection armed: {plan:?})");
    }
    let max_requeues = args.get_usize("max-requeues", 3)? as u32;
    // overload hardening: bounded intake + graceful-shutdown drain bound
    let max_queue_depth = args.get_usize("max-queue", 0)?;
    let drain_ms = args.get_usize("drain-ms", 5_000)? as u64;
    let cfg = ServeConfig::builder()
        .lanes(lanes)
        .mode(NumericsMode::DesktopF32)
        .sim_model(LlmConfig::llama2_7b())
        .kv_block_len(kv_block_len)
        .kv_pool_blocks(kv_pool_blocks)
        .prefill_chunk(prefill_chunk)
        .adaptive_prefill(args.get_bool("adaptive-prefill"))
        .workers(workers)
        .faults(faults)
        .max_requeues(max_requeues)
        .max_queue_depth(max_queue_depth)
        .drain_ms(drain_ms)
        .build()?;

    let report = if let Some(listen) = args.get("listen") {
        // continuous serving behind the HTTP/SSE front door: requests
        // arrive over the wire and join the running batch mid-flight;
        // Ctrl-C drains gracefully through the engine's drain bound
        let http_timeout_ms = args.get_usize("http-timeout-ms", 5_000)? as u64;
        let http_cfg = HttpServerConfig {
            listen: listen.to_string(),
            max_wall_ms: args.get_usize("serve-wall-ms", 0)? as u64,
            max_requests: 0,
            read_timeout_ms: http_timeout_ms,
            write_timeout_ms: http_timeout_ms,
            install_sigint: true,
        };
        let rep = serve_http(&tm, cfg, &http_cfg, |addr| {
            println!("listening on http://{addr} (POST /v1/generate, GET /healthz)");
        })
        .map_err(|e| e.to_string())?;
        println!(
            "front door: {} connections, {} requests served",
            rep.connections, rep.requests_served
        );
        rep.report
    } else {
        // offline: drain a synthetic workload through the same engine
        let reqs = WorkloadGen::new(workload_spec(args, tm.vocab)?).generate();
        CpuServer::new(&tm, cfg).serve(reqs)
    };
    println!("{}", report.metrics.format_table());
    let pool = &report.kv_pool;
    println!(
        "kv pool: {} blocks x {} tokens ({:.2} MiB incl. Q15.17 mirror), row width {}",
        pool.total_blocks(),
        pool.block_len(),
        (pool.total_blocks() * pool.bytes_per_block()) as f64 / (1024.0 * 1024.0),
        pool.row_width(),
    );
    if pool.free_blocks() != pool.total_blocks() {
        return Err(format!(
            "kv pool leak: {} of {} blocks still held at shutdown",
            pool.total_blocks() - pool.free_blocks(),
            pool.total_blocks()
        ));
    }
    Ok(())
}

fn run() -> Result<(), String> {
    let args = Args::parse(
        &[
            "only", "model", "ctx", "requests", "batch", "gap-ms", "seed", "sequences", "len",
            "kv-heads", "kv-block-len", "kv-pool-blocks", "prefill-chunk", "prompt-len", "workers",
            "deadline-ms", "faults", "max-requeues", "listen", "serve-wall-ms", "max-queue",
            "drain-ms", "http-timeout-ms",
        ],
        &["help", "adaptive-prefill"],
    )?;
    let cmd = args
        .positional()
        .first()
        .map(|s| s.as_str())
        .unwrap_or("exhibits");
    let arch = ArchConfig::default();

    match cmd {
        "exhibits" => {
            let only = args.get("only");
            let all: Vec<(&str, String)> = vec![
                ("fig7a", report::fig7a(&arch)),
                ("fig7b", report::fig7b(&arch)),
                ("explut", report::exp_lut_error()),
                ("table2", report::table2(&arch)),
                ("fig8a", report::fig8a(&arch, &LlmConfig::llama2_7b(), 512)),
                ("table3", report::table3(&arch)),
                ("fig8b", report::fig8b(&arch)),
                ("table4", report::table4(&arch)),
            ];
            for (name, text) in all {
                if only.is_none_or(|o| o == name) {
                    println!("{text}");
                }
            }
        }
        "simulate" => {
            let cfg = model_by_name(args.get_or("model", "llama2-7b"))?;
            let ctx = args.get_usize("ctx", 512)?;
            let sim = layer_sched::simulate_token(&arch, &cfg, ctx);
            println!(
                "{} @ ctx {ctx}: {:.2} ms/token, {:.1} token/s ({} cycles)",
                cfg.name, sim.latency_ms, sim.tokens_per_s, sim.total_cycles
            );
            println!("{}", report::fig8a(&arch, &cfg, ctx));
        }
        "serve" => {
            // PJRT engine when compiled in and artifacts exist; otherwise
            // the CPU backend over the fused decode kernels.
            #[cfg(feature = "pjrt")]
            {
                if artifacts_available() {
                    return serve_pjrt(&args);
                }
            }
            serve_cpu(&args)?;
        }
        "accuracy" => {
            if !artifacts_available() {
                return Err("artifacts not built — run `make artifacts`".into());
            }
            let ws = WeightStore::load(&default_artifacts_dir()).map_err(|e| e.to_string())?;
            let tm = TinyModel::load(&ws).map_err(|e| e.to_string())?;
            let sequences = args.get_usize("sequences", 20)?;
            let len = args.get_usize("len", 48)?;
            let (table, _) = report::table1(&tm, sequences, len);
            println!("{table}");
        }
        "help" | "--help" => {
            println!("subcommands: exhibits | simulate | serve | accuracy");
        }
        other => return Err(format!("unknown subcommand '{other}'")),
    }
    Ok(())
}
