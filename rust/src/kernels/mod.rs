//! Fused multi-head decode kernels — the software hot-path substrate.
//!
//! The paper's SwiftKV-MHA accelerator derives its 13.48× attention
//! latency reduction from a *fused* schedule (§IV, Fig. 5): every
//! `(k_t, v_t)` cache row is streamed exactly once and feeds all heads in
//! a uniform pipeline; no per-head re-scan, no intermediate buffers. This
//! module is the same restructuring applied to the Rust model:
//!
//! - [`isa`] — the runtime ISA dispatch table: every hot microkernel
//!   (f32 `dot`/`axpy`/`scale_axpy`/`scale`, the Q15.17 wide dot and
//!   AXPY updates, the INT8 dot and W4A8 column MAC) is a `fn` pointer
//!   selected once per process from CPU feature detection
//!   (`SWIFTKV_ISA=scalar|avx2|neon` overrides for testing),
//! - [`simd`] — the f32 primitive facade over the dispatch table, with
//!   the portable `chunks_exact` multi-accumulator scalar fallback
//!   (hand-written AVX2 and NEON implementations live in `simd_avx2` /
//!   `simd_neon`),
//! - [`mha::MhaSwiftKv`] — all heads' `(μ, Z, Y)` state packed
//!   contiguously, advanced per interleaved cache row in a single sweep
//!   (f32 numerics). Grouped-query attention is first-class: with
//!   `n_kv_heads < n_heads` each KV row shrinks to `n_kv_heads · d` and
//!   every KV-head slice advances its whole group of query heads,
//! - [`fxp_mha::FxpMhaSwiftKv`] — the same fused sweep in the
//!   accelerator's Q15.17 + LUT-exp arithmetic, bit-exact vs. the
//!   per-head [`crate::attention::fxp_swiftkv`] datapath,
//! - [`paged::BlockPool`] / [`paged::BlockTable`] — the paged KV cache:
//!   fixed-size blocks of interleaved rows drawn from one shared pool by
//!   every sequence, walked by the `extend_paged` sweeps with the same
//!   per-head op order (f32 bit-identical, Q15.17 bit-exact vs the
//!   contiguous path),
//! - [`scratch::DecodeScratch`] — caller-owned buffers making a
//!   steady-state [`crate::model::TinyModel`] decode step allocation-free
//!   (KV-side buffers sized `n_kv_heads · d_head` under GQA/MQA),
//! - [`scratch::BatchScratch`] — the batch-width twin: gathered INT8
//!   activation rows and batched GEMM outputs for
//!   [`crate::model::TinyModel::decode_steps_into`], grown once to the
//!   high-water batch width (`ensure_batch`), allocation-free after,
//! - [`pool::WorkerPool`] — persistent worker threads for operator-level
//!   parallelism (batched GEMMs split by output columns, the attention
//!   phase by lanes) with zero-alloc job dispatch, replacing the
//!   per-iteration `std::thread::scope` spawns of the old serving loop.
//!
//! Ground truth for all of the above is the deliberately naive scalar
//! oracle in [`crate::util::oracle`] (materialized scores, two-pass
//! softmax), which `tests/prop_gqa_fused.rs` sweeps across MQA/GQA/MHA
//! shapes.
//!
//! The non-allocating `_into` companions on the quant side
//! ([`crate::quant::gemv_w4a8_into`], [`crate::quant::quantize_int8_into`],
//! [`crate::quant::QuantLinear::forward_into`]) are re-exported here so
//! the whole fused-kernel surface is reachable from one path.

pub mod fxp_mha;
pub mod isa;
pub mod mha;
pub mod paged;
pub mod pool;
pub mod scratch;
pub mod simd;
// `not(miri)`: the intrinsic kernels are opaque to Miri (vendor
// intrinsics are unsupported), and the scalar table is the semantic
// ground truth anyway — the Miri tier pins `SWIFTKV_ISA=scalar` and
// never reaches these modules.
#[cfg(all(target_arch = "x86_64", not(miri)))]
pub(crate) mod simd_avx2;
#[cfg(all(target_arch = "aarch64", not(miri)))]
pub(crate) mod simd_neon;
pub mod sync;

pub use crate::quant::{gemv_w4a8_into, quantize_int8_into};
pub use fxp_mha::FxpMhaSwiftKv;
pub use mha::MhaSwiftKv;
pub use paged::{BlockPool, BlockTable, KvBlock};
pub use pool::{SharedMut, WorkerPool};
pub use scratch::{BatchScratch, DecodeScratch};
pub use simd::{axpy, dot, scale, scale_axpy};

/// Gather one head of a token-major interleaved cache
/// (`[len][n_heads * d]`) into a contiguous head-major `[len, d]`
/// buffer — the layout the per-head [`crate::attention`] paths consume.
/// Used by the fused-vs-per-head equivalence tests and for layout
/// debugging.
pub fn gather_head(cache: &[f32], head: usize, n_heads: usize, d: usize, len: usize) -> Vec<f32> {
    assert!(head < n_heads, "head out of range");
    assert!(cache.len() >= len * n_heads * d, "cache too short");
    let mut out = Vec::with_capacity(len * d);
    for t in 0..len {
        let at = (t * n_heads + head) * d;
        out.extend_from_slice(&cache[at..at + d]);
    }
    out
}
