//! Blockwise Flash-style attention [10] adapted to the decode setting —
//! the comparison baseline of Fig. 7(a).
//!
//! The KV cache is processed in fixed blocks of size `B`. Within a block,
//! scores are materialized, a block max is taken, and the running
//! accumulators are rescaled once per block (the GPU-oriented blockwise
//! softmax). During decode the context rarely ends on a block boundary, so
//! the final partial block is padded to `B` — the "wait for block" effect
//! the paper calls out (§I); the cycle model charges for the padded work.

use super::{dot_f32, HeadProblem};

/// Flash-attention accumulator state (block-level online softmax).
#[derive(Debug, Clone)]
pub struct FlashState {
    pub m: f32,
    pub z: f32,
    pub acc: Vec<f32>,
    pub blocks_processed: usize,
}

impl FlashState {
    pub fn new(d: usize) -> Self {
        FlashState {
            m: f32::NEG_INFINITY,
            z: 0.0,
            acc: vec![0.0; d],
            blocks_processed: 0,
        }
    }

    /// Merge one block of (scores, value rows). `values` is `[n, d]`
    /// row-major with `n == scores.len()`.
    pub fn merge_block(&mut self, scores: &[f32], values: &[f32], d: usize) {
        let n = scores.len();
        debug_assert_eq!(values.len(), n * d);
        let block_max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let new_m = self.m.max(block_max);
        if !new_m.is_finite() {
            // fully-masked (padded) block: nothing to fold in
            self.blocks_processed += 1;
            return;
        }
        let alpha = if self.m.is_finite() {
            (self.m - new_m).exp()
        } else {
            0.0
        };
        let mut z_blk = 0.0f32;
        let mut y_blk = vec![0.0f32; d];
        for (t, &s) in scores.iter().enumerate() {
            if !s.is_finite() {
                continue; // padding lane
            }
            let w = (s - new_m).exp();
            z_blk += w;
            for (y, &v) in y_blk.iter_mut().zip(&values[t * d..(t + 1) * d]) {
                *y += w * v;
            }
        }
        self.z = alpha * self.z + z_blk;
        for (a, y) in self.acc.iter_mut().zip(&y_blk) {
            *a = alpha * *a + y;
        }
        self.m = new_m;
        self.blocks_processed += 1;
    }

    pub fn finalize(&self) -> Vec<f32> {
        assert!(self.z > 0.0, "finalize with empty state");
        self.acc.iter().map(|a| a / self.z).collect()
    }
}

/// Number of blocks (including the padded final one) for a context length.
pub fn num_blocks(len: usize, block: usize) -> usize {
    len.div_ceil(block)
}

/// Blockwise attention with block size `block`.
pub fn attend(p: &HeadProblem, block: usize) -> Vec<f32> {
    assert!(block >= 1);
    let scale = p.scale();
    let mut st = FlashState::new(p.d);
    let mut scores = vec![0.0f32; block];
    let mut values = vec![0.0f32; block * p.d];
    for b in 0..num_blocks(p.len, block) {
        let start = b * block;
        let n = block.min(p.len - start); // valid rows in this block
        for i in 0..block {
            if i < n {
                scores[i] = dot_f32(p.q, p.key(start + i)) * scale;
                values[i * p.d..(i + 1) * p.d].copy_from_slice(p.value(start + i));
            } else {
                scores[i] = f32::NEG_INFINITY; // decode-boundary padding
            }
        }
        st.merge_block(&scores, &values, p.d);
    }
    st.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{assert_close, ProblemData};
    use crate::attention::{native, swiftkv};

    #[test]
    fn matches_native_for_all_paper_block_sizes() {
        for &block in &[8usize, 16, 32] {
            for seed in 0..4 {
                let data = ProblemData::random(seed, 16, 100 + seed as usize * 31, 1.0);
                let p = data.problem();
                assert_close(
                    &attend(&p, block),
                    &native::attend(&p),
                    1e-5,
                    &format!("block {block} seed {seed}"),
                );
            }
        }
    }

    #[test]
    fn partial_final_block_handled() {
        // len deliberately not a multiple of the block size
        let data = ProblemData::random(3, 8, 37, 1.0);
        let p = data.problem();
        assert_close(&attend(&p, 16), &native::attend(&p), 1e-5, "partial block");
    }

    #[test]
    fn block_one_equals_swiftkv_per_token() {
        let data = ProblemData::random(6, 16, 50, 1.0);
        let p = data.problem();
        assert_close(&attend(&p, 1), &swiftkv::attend(&p), 1e-5, "block=1");
    }

    #[test]
    fn block_count_includes_padding() {
        assert_eq!(num_blocks(512, 32), 16);
        assert_eq!(num_blocks(513, 32), 17);
        assert_eq!(num_blocks(1, 32), 1);
        assert_eq!(num_blocks(32, 32), 1);
    }

    #[test]
    fn fully_masked_block_is_noop() {
        let mut st = FlashState::new(2);
        st.merge_block(&[1.0, 2.0], &[1.0, 0.0, 0.0, 1.0], 2);
        let before = st.clone();
        st.merge_block(
            &[f32::NEG_INFINITY, f32::NEG_INFINITY],
            &[9.0, 9.0, 9.0, 9.0],
            2,
        );
        assert_eq!(st.m, before.m);
        assert_eq!(st.z, before.z);
        assert_eq!(st.acc, before.acc);
    }
}
