//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Used by workload generators, synthetic-data builders and the property
//! tests. Deterministic across platforms (pure integer arithmetic).

/// xoshiro256** generator seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion (Vigna's recommended seeding)
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw u64 (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // take the top 53 bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn gen_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.gen_f32() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gen_normal()).collect()
    }

    /// Vector of uniforms in [-scale, scale).
    pub fn uniform_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| (self.gen_f32() - 0.5) * 2.0 * scale).collect()
    }

    /// Sample an exponential with the given mean (Poisson inter-arrivals).
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        -mean * self.gen_f64().max(1e-12).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::seed_from_u64(3);
        let mean: f64 = (0..20000).map(|_| r.gen_f64()).sum::<f64>() / 20000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let xs: Vec<f32> = (0..20000).map(|_| r.gen_normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::seed_from_u64(5);
        for _ in 0..1000 {
            let x = r.gen_range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
