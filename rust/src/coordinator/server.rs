//! The serving loop: queue → batcher → engine step → sample → retire.

use super::batcher::Batcher;
use super::metrics::{Percentiles, ServeMetrics};
use super::session::Session;
use crate::model::{tiny, LlmConfig, Request};
use crate::runtime::Engine;
use crate::sim::{layer_sched, ArchConfig};
use anyhow::{anyhow, Result};
use std::time::Instant;

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Batch variant to run (must be a compiled variant). `None` picks the
    /// largest available.
    pub batch: Option<usize>,
    /// Safety cap on engine iterations (0 = unlimited).
    pub max_iterations: u64,
    /// Model config used for the simulated-accelerator metrics.
    pub sim_model: LlmConfig,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            batch: None,
            max_iterations: 0,
            sim_model: LlmConfig::llama2_7b(),
        }
    }
}

/// Result of a serving run.
pub struct ServeReport {
    pub sessions: Vec<Session>,
    pub metrics: ServeMetrics,
}

/// The decode server.
pub struct Server<'e> {
    engine: &'e Engine,
    opts: ServeOptions,
}

impl<'e> Server<'e> {
    pub fn new(engine: &'e Engine, opts: ServeOptions) -> Self {
        Server { engine, opts }
    }

    /// Serve a request stream to completion (arrival times are honoured in
    /// iteration order: a request is only admittable once the wall clock
    /// passes its `arrival_ms`).
    pub fn serve(&self, requests: Vec<Request>) -> Result<ServeReport> {
        let batch = match self.opts.batch {
            Some(b) => b,
            None => *self
                .engine
                .batch_variants()
                .last()
                .ok_or_else(|| anyhow!("no batch variants"))?,
        };
        let n_ctx = self.engine.manifest.n_ctx;
        let vocab = self.engine.manifest.vocab;
        let mut batcher = Batcher::new(batch, n_ctx);
        let mut state = self.engine.new_state(batch)?;

        let mut pending: std::collections::VecDeque<Request> = requests.into();
        let t0 = Instant::now();
        let mut iteration = 0u64;
        let mut step_ms: Vec<f64> = Vec::new();
        let mut occupancy_acc = 0.0;
        let mut sim_cycles: u64 = 0;
        let arch = ArchConfig::default();
        // iteration timestamps for latency accounting
        let mut iter_end_ms: Vec<f64> = Vec::new();

        loop {
            // admit every request whose arrival time has passed
            let now_ms = t0.elapsed().as_secs_f64() * 1e3;
            while pending.front().is_some_and(|r| r.arrival_ms as f64 <= now_ms) {
                if let Some(r) = pending.pop_front() {
                    if batcher.submit(r).is_err() {
                        // rejected (oversized); drop
                    }
                }
            }
            batcher.admit(iteration);
            if batcher.is_drained() {
                if pending.is_empty() {
                    break;
                }
                // idle until the next arrival
                std::thread::sleep(std::time::Duration::from_micros(200));
                continue;
            }

            let (tokens, positions, active) = batcher.gather_inputs();
            occupancy_acc += batcher.occupancy();

            let ts = Instant::now();
            let logits = self.engine.decode_step(&mut state, &tokens, &positions)?;
            step_ms.push(ts.elapsed().as_secs_f64() * 1e3);

            // simulated accelerator cost for this step: one decode step at
            // the largest live context in the batch
            let max_ctx = positions
                .iter()
                .zip(&active)
                .filter(|(_, a)| **a)
                .map(|(p, _)| *p as usize + 1)
                .max()
                .unwrap_or(1);
            sim_cycles +=
                layer_sched::simulate_token(&arch, &self.opts.sim_model, max_ctx).total_cycles;

            // greedy sample per lane
            let samples: Vec<u32> = (0..batch)
                .map(|i| tiny::argmax(&logits[i * vocab..(i + 1) * vocab]) as u32)
                .collect();
            batcher.scatter_outputs(&samples, iteration);
            iter_end_ms.push(t0.elapsed().as_secs_f64() * 1e3);

            iteration += 1;
            if self.opts.max_iterations > 0 && iteration >= self.opts.max_iterations {
                break;
            }
        }

        let wall_s = t0.elapsed().as_secs_f64();
        let (requests_admitted, requests_rejected) = batcher.counters();
        let fc = batcher.fault_counters();
        let sessions = batcher.finished;
        let total_tokens: usize = sessions.iter().map(|s| s.generated.len()).sum();
        let at_ms = |it: u64| -> f64 {
            iter_end_ms
                .get(it as usize)
                .copied()
                .unwrap_or(wall_s * 1e3)
        };
        let latencies: Vec<f64> = sessions
            .iter()
            .filter_map(|s| s.finished_at.map(|f| at_ms(f) - at_ms(s.admitted_at) + 0.0))
            .collect();
        let ttfts: Vec<f64> = sessions
            .iter()
            .filter_map(|s| s.first_token_at.map(|f| at_ms(f) - at_ms(s.admitted_at)))
            .collect();

        let sim_ms = arch.cycles_to_ms(sim_cycles);
        let metrics = ServeMetrics {
            requests: sessions.len(),
            requests_admitted,
            requests_rejected,
            requests_failed: fc.failed,
            preemptions: fc.preemptions,
            requeues: fc.requeues,
            deadline_expired: fc.deadline_expired,
            total_tokens_generated: total_tokens,
            iterations: iteration,
            wall_s,
            step_ms: Percentiles::compute(&step_ms).unwrap_or(Percentiles::ZERO),
            request_latency_ms: Percentiles::compute(&latencies).unwrap_or(Percentiles::ZERO),
            ttft_ms: Percentiles::compute(&ttfts).unwrap_or(Percentiles::ZERO),
            mean_occupancy: if iteration > 0 {
                occupancy_acc / iteration as f64
            } else {
                0.0
            },
            // the PJRT executable is inherently batched: every iteration
            // is one engine call over the whole lane array — one weight
            // pass per step by construction (width not tracked here)
            batch_width: Percentiles::ZERO,
            weight_passes: iteration,
            weight_passes_per_step: if iteration > 0 { 1.0 } else { 0.0 },
            tokens_per_s: total_tokens as f64 / wall_s,
            simulated_accel_ms: sim_ms,
            simulated_tokens_per_s: if sim_ms > 0.0 {
                total_tokens as f64 / (sim_ms / 1e3)
            } else {
                0.0
            },
            // queueing/TPOT stats are a continuous-engine concern; the
            // PJRT loop drains a fixed list and leaves them zeroed
            ..Default::default()
        };
        Ok(ServeReport { sessions, metrics })
    }
}
