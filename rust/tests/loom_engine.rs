//! Model-checked tests for the engine's park/wake/shutdown gate
//! ([`EngineGate`]): the eventcount protocol between submitters and the
//! serving loop's idle park.
//!
//! Built only under `RUSTFLAGS="--cfg loom"`, which swaps the
//! `kernels::sync` alias layer from `std` to the in-tree model checker
//! (`swiftkv::util::mc`). Each body is re-executed across a bounded DFS
//! of interleavings; a lost wakeup shows up as a non-terminating
//! schedule (reported as a deadlock by the checker), a lost submission
//! as a failed assert.
//!
//! The protocol under test (see `coordinator/submit.rs`):
//! 1. submitter: enqueue work, then `notify()` (bump `seq` under the
//!    lock, notify_all);
//! 2. engine: snapshot `seq()` *before* draining the intake, then
//!    `park(seen, None)` — the park re-checks under the same lock, so
//!    a notify between snapshot and park never sleeps through.

#![cfg(loom)]

use swiftkv::coordinator::EngineGate;
use swiftkv::kernels::sync::{thread, Arc, Mutex};
use swiftkv::util::mc;

fn drain(queue: &Mutex<Vec<u32>>) -> usize {
    let mut q = queue.lock().expect("gate model queue poisoned");
    let n = q.len();
    q.clear();
    n
}

#[test]
fn submission_wakeup_is_never_lost() {
    // One producer races one parking consumer. Whatever the schedule —
    // notify lands before the seq snapshot, between snapshot and park,
    // or while parked — the consumer must observe the submission and
    // terminate.
    let report = mc::model(|| {
        let gate = Arc::new(EngineGate::new());
        let queue = Arc::new(Mutex::new(Vec::new()));
        let (g, q) = (gate.clone(), queue.clone());
        let producer = thread::spawn(move || {
            q.lock().expect("gate model queue poisoned").push(7u32);
            g.notify();
        });
        let mut drained = 0usize;
        loop {
            let seen = gate.seq();
            drained += drain(&queue);
            if drained == 1 {
                break;
            }
            gate.park(seen, None);
        }
        producer.join().expect("model thread panicked");
        assert_eq!(drained, 1, "submission lost across park/wake");
    });
    eprintln!("submission_wakeup_is_never_lost: {report:?}");
}

#[test]
fn shutdown_terminates_a_parked_engine() {
    // The engine snapshots seq while idle and parks with no timeout; a
    // concurrent shutdown request must wake it from any state (already
    // parked, about to park, or not yet parked).
    let report = mc::model(|| {
        let gate = Arc::new(EngineGate::new());
        let seen = gate.seq();
        let g = gate.clone();
        let closer = thread::spawn(move || g.request_shutdown());
        gate.park(seen, None);
        assert!(gate.shutdown_requested(), "park returned without the latch");
        closer.join().expect("model thread panicked");
    });
    eprintln!("shutdown_terminates_a_parked_engine: {report:?}");
}

#[test]
fn intake_close_terminates_a_parked_engine() {
    // Same shape as shutdown, for the handle-drop path: the last
    // `ServeHandle` clone latches `close_intake()` before its mpsc
    // sender disconnects, and that latch alone must unpark the engine.
    let report = mc::model(|| {
        let gate = Arc::new(EngineGate::new());
        let seen = gate.seq();
        let g = gate.clone();
        let closer = thread::spawn(move || g.close_intake());
        gate.park(seen, None);
        assert!(gate.intake_closed(), "park returned without the latch");
        closer.join().expect("model thread panicked");
    });
    eprintln!("intake_close_terminates_a_parked_engine: {report:?}");
}

#[test]
fn shutdown_never_strands_a_buffered_submission() {
    // A submission and a shutdown race: the producer enqueues, notifies,
    // then requests shutdown. The consumer must both terminate and —
    // because the engine drains its intake once more after observing the
    // latch — account for the submission in every interleaving.
    let report = mc::model(|| {
        let gate = Arc::new(EngineGate::new());
        let queue = Arc::new(Mutex::new(Vec::new()));
        let (g, q) = (gate.clone(), queue.clone());
        let producer = thread::spawn(move || {
            q.lock().expect("gate model queue poisoned").push(7u32);
            g.notify();
            g.request_shutdown();
        });
        let mut drained = 0usize;
        loop {
            let seen = gate.seq();
            drained += drain(&queue);
            if gate.shutdown_requested() {
                break;
            }
            gate.park(seen, None);
        }
        producer.join().expect("model thread panicked");
        // Final drain after the latch, mirroring the engine's shutdown
        // pass: anything buffered before close must still be seen.
        drained += drain(&queue);
        assert_eq!(drained, 1, "submission stranded by shutdown");
        assert!(gate.shutdown_requested());
    });
    eprintln!("shutdown_never_strands_a_buffered_submission: {report:?}");
}
