//! Loader for the AOT weight blob (`artifacts/weights.bin` +
//! `artifacts/manifest.json`).
//!
//! The manifest lists every parameter array with dtype/shape/offset in the
//! exact order of the HLO input signature; the blob holds the raw
//! little-endian bytes at 64-byte alignment. Loaded once at startup —
//! never on the request path.

use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// One parameter array's metadata.
#[derive(Debug, Clone)]
pub struct ArrayMeta {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// The tiny model's configuration as recorded by `aot.py`.
#[derive(Debug, Clone)]
pub struct TinyManifest {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads (GQA/MQA). Older manifests omit this; it defaults to
    /// `n_heads` (plain MHA).
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub n_layers: usize,
    pub d_ffn: usize,
    pub n_ctx: usize,
    pub rope_base: f64,
    pub batch_variants: Vec<usize>,
    pub artifact_files: Vec<(String, String)>,
}

/// Weight blob + parsed manifest.
pub struct WeightStore {
    blob: Vec<u8>,
    arrays: Vec<ArrayMeta>,
    pub manifest: TinyManifest,
}

impl WeightStore {
    /// Load from an artifacts directory.
    pub fn load(dir: &Path) -> Result<WeightStore> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let root = Json::parse(&manifest_text).map_err(|e| anyhow!("manifest: {e}"))?;

        let model = root.get("model").ok_or_else(|| anyhow!("manifest: no model"))?;
        let g = |k: &str| -> Result<usize> {
            model
                .get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest: model.{k} missing"))
        };
        let mut artifact_files = Vec::new();
        if let Some(arts) = root.get("artifacts").and_then(Json::as_obj) {
            for (k, v) in arts {
                if let Some(f) = v.get("file").and_then(Json::as_str) {
                    artifact_files.push((k.clone(), f.to_string()));
                }
            }
        }
        let n_heads = g("n_heads")?;
        // absent → MHA default; present but malformed → hard error (don't
        // silently drop a declared GQA shape)
        let n_kv_heads = match model.get("n_kv_heads") {
            None => n_heads,
            Some(j) => j
                .as_usize()
                .ok_or_else(|| anyhow!("manifest: model.n_kv_heads is not an integer"))?,
        };
        if n_kv_heads == 0 || n_heads % n_kv_heads != 0 {
            bail!("manifest: n_heads ({n_heads}) must be a multiple of n_kv_heads ({n_kv_heads})");
        }
        let manifest = TinyManifest {
            vocab: g("vocab")?,
            d_model: g("d_model")?,
            n_heads,
            n_kv_heads,
            d_head: g("d_head")?,
            n_layers: g("n_layers")?,
            d_ffn: g("d_ffn")?,
            n_ctx: g("n_ctx")?,
            rope_base: model
                .get("rope_base")
                .and_then(Json::as_f64)
                .unwrap_or(10000.0),
            batch_variants: root
                .get("batch_variants")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            artifact_files,
        };

        let weights = root
            .get("weights")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: no weights table"))?;
        let mut arrays = Vec::with_capacity(weights.len());
        for w in weights {
            let s = |k: &str| -> Result<String> {
                Ok(w.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("weights entry missing {k}"))?
                    .to_string())
            };
            let u = |k: &str| -> Result<usize> {
                w.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("weights entry missing {k}"))
            };
            arrays.push(ArrayMeta {
                name: s("name")?,
                dtype: s("dtype")?,
                shape: w
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
                offset: u("offset")?,
                nbytes: u("nbytes")?,
            });
        }

        let blob = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("reading weights.bin in {}", dir.display()))?;
        for a in &arrays {
            if a.offset + a.nbytes > blob.len() {
                bail!("array {} overruns blob", a.name);
            }
        }
        Ok(WeightStore {
            blob,
            arrays,
            manifest,
        })
    }

    /// Parameter arrays in HLO-signature order.
    pub fn arrays(&self) -> &[ArrayMeta] {
        &self.arrays
    }

    fn meta(&self, name: &str) -> Result<&ArrayMeta> {
        self.arrays
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("no array '{name}'"))
    }

    /// Raw bytes of an array.
    pub fn bytes(&self, name: &str) -> Result<&[u8]> {
        let m = self.meta(name)?;
        Ok(&self.blob[m.offset..m.offset + m.nbytes])
    }

    /// f32 copy of an array (little-endian decode).
    pub fn f32_vec(&self, name: &str) -> Result<Vec<f32>> {
        let m = self.meta(name)?;
        if m.dtype != "float32" {
            bail!("array {name} is {}, not float32", m.dtype);
        }
        let raw = self.bytes(name)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// i8 copy of an array.
    pub fn i8_vec(&self, name: &str) -> Result<Vec<i8>> {
        let m = self.meta(name)?;
        if m.dtype != "int8" {
            bail!("array {name} is {}, not int8", m.dtype);
        }
        Ok(self.bytes(name)?.iter().map(|&b| b as i8).collect())
    }

    /// Shape of an array.
    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        Ok(&self.meta(name)?.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn store() -> Option<WeightStore> {
        let dir = artifacts_dir();
        dir.join("manifest.json").exists().then(|| WeightStore::load(&dir).unwrap())
    }

    #[test]
    fn loads_manifest_and_blob() {
        let Some(ws) = store() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(ws.manifest.d_model, ws.manifest.n_heads * ws.manifest.d_head);
        // older manifests carry no n_kv_heads entry — MHA default applies
        assert!(ws.manifest.n_kv_heads >= 1);
        assert_eq!(ws.manifest.n_heads % ws.manifest.n_kv_heads, 0);
        assert!(!ws.arrays().is_empty());
        assert!(!ws.manifest.artifact_files.is_empty());
    }

    #[test]
    fn embedding_shape_and_content() {
        let Some(ws) = store() else {
            return;
        };
        let emb = ws.f32_vec("embedding").unwrap();
        let shape = ws.shape("embedding").unwrap();
        assert_eq!(shape, &[ws.manifest.vocab, ws.manifest.d_model]);
        assert_eq!(emb.len(), shape.iter().product::<usize>());
        assert!(emb.iter().all(|x| x.is_finite()));
        assert!(emb.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn quantized_weights_in_int4_range() {
        let Some(ws) = store() else {
            return;
        };
        let wq = ws.i8_vec("layer0.wq.q").unwrap();
        assert!(wq.iter().all(|&v| (-7..=7).contains(&(v as i32))));
        let scales = ws.f32_vec("layer0.wq.scale").unwrap();
        assert!(scales.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn wrong_dtype_rejected() {
        let Some(ws) = store() else {
            return;
        };
        assert!(ws.f32_vec("layer0.wq.q").is_err());
        assert!(ws.i8_vec("embedding").is_err());
        assert!(ws.bytes("nonexistent").is_err());
    }
}
